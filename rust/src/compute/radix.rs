//! Tuner-dispatched radix data plane (DESIGN.md §8).
//!
//! The sort-family workloads move uniform-ish u64 keys, which is exactly
//! the shape where counting kernels beat comparison sorts. Since the
//! tuner layer landed, [`RadixCompute`] is not one kernel but a family
//! dispatched per block by a [`Tuner`](super::Tuner) (see
//! [`super::tuner`] for the taxonomy and policy):
//!
//! - **comparative** — std comparison sorts below the crossover.
//! - **lsb** — LSD radix over 8-bit digits, modeled on the
//!   `lsb_radix_sort` kernels of the ska-sort family: one histogram pass
//!   computes all eight digit distributions, trivial digits (every key
//!   shares the byte — common once keys are confined to a bucket's
//!   sub-range) are skipped, and the remaining digits scatter between
//!   the key buffer and one scratch buffer. LSD scatter is stable, which
//!   is what makes the pair kernel's tie-break hold by construction.
//! - **ska** — MSD at the block's digit level: an in-place American-flag
//!   cycle-chasing partition for bare keys, a stable out-of-place
//!   scatter for pairs; each bucket re-enters the tuner one level down,
//!   so sub-blocks finish on whatever kernel fits their size.
//! - **mt_oop / regions** — the parallel kernels: a top-byte split into
//!   ≤ 256 disjoint bucket ranges whose sorts tile across the worker
//!   pool shared with the executor ([`crate::pool`]). `mt_oop` scatters
//!   stably out of place then LSD-sorts each bucket (output is
//!   worker-count independent by construction); `regions` partitions in
//!   place (unstable → bare keys only).
//!
//! Every kernel produces the §8-canonical output for its call site, so
//! the tuner's choice — and the `NANOSORT_TUNER` override — is invisible
//! in digests; `rust/tests/compute.rs` and `rust/tests/compute_tuner.rs`
//! pin radix-vs-oracle equality across every algorithm, distribution,
//! threshold-straddling size, and edge shape.
//!
//! [`RadixCompute::partition`] / [`RadixCompute::partition_pairs`] are
//! single-kernel: one tag+count pass, then a direct scatter into
//! per-bucket buffers allocated at exact capacity (no push-time
//! reallocation, no intermediate bucket-index `Vec` handed back).

use std::sync::Arc;

use super::tuner::{
    Algorithm, KernelCounts, StandardTuner, Tuner, TunerOverride, TuningParams,
    DEFAULT_CROSSOVER,
};
use super::{LocalCompute, NativeCompute};
use crate::pool::WorkerPool;

/// Digit width of one radix pass.
const RADIX_BITS: u32 = 8;
/// Buckets per pass (2^RADIX_BITS).
const BUCKETS: usize = 1 << RADIX_BITS;
/// Radix passes covering a u64.
const LEVELS: usize = (u64::BITS / RADIX_BITS) as usize;
/// The most significant digit level (where caller-facing sorts start).
const TOP_LEVEL: usize = LEVELS - 1;
/// Pivot-list length up to which the branchless linear scan beats binary
/// search for bucket tagging.
const LINEAR_SCAN_PIVOTS: usize = 32;

/// Radix-kernel implementation of [`LocalCompute`]; the default data
/// plane (`--compute radix`). Reductions (`min`, `median_combine`) have
/// no radix structure to exploit and delegate to the oracle.
///
/// Cloning shares the tuner, worker pool, and kernel-dispatch counters
/// (all `Arc`), so a plane handed to shard workers and the BENCH
/// reporter observes one histogram.
#[derive(Clone)]
pub struct RadixCompute {
    tuner: Arc<dyn Tuner>,
    force: Option<TunerOverride>,
    crossover: usize,
    pool: Arc<WorkerPool>,
    counts: Arc<KernelCounts>,
}

impl std::fmt::Debug for RadixCompute {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RadixCompute")
            .field("tuner", &self.tuner.name())
            .field("force", &self.force)
            .field("crossover", &self.crossover)
            .field("threads", &self.pool.budget())
            .finish()
    }
}

impl Default for RadixCompute {
    /// A sequential plane (pool budget 1, no parallel kernels), still
    /// honoring `NANOSORT_TUNER` for the sequential families.
    fn default() -> Self {
        RadixCompute::with_pool(Arc::new(WorkerPool::new(1)))
    }
}

impl RadixCompute {
    /// A plane backed by `pool` (the budget shared with the executor),
    /// with the kernel override read from `NANOSORT_TUNER` (panics on a
    /// malformed value; unset = auto).
    pub fn with_pool(pool: Arc<WorkerPool>) -> Self {
        RadixCompute::forced(TunerOverride::from_env(), pool)
    }

    /// A plane with an explicit override, bypassing the environment —
    /// what tests and the `tunersweep` benchfig use, so they never
    /// mutate process-global env state under a parallel test harness.
    pub fn forced(force: Option<TunerOverride>, pool: Arc<WorkerPool>) -> Self {
        RadixCompute {
            tuner: Arc::new(StandardTuner),
            force,
            crossover: DEFAULT_CROSSOVER,
            pool,
            counts: Arc::new(KernelCounts::default()),
        }
    }

    /// Replace the kernel-selection policy.
    pub fn with_tuner(mut self, tuner: Arc<dyn Tuner>) -> Self {
        self.tuner = tuner;
        self
    }

    /// Override the comparison-fallback crossover (default
    /// [`DEFAULT_CROSSOVER`]); carried in [`TuningParams`] so policies
    /// and boundary tests see the same value the dispatcher uses.
    pub fn with_crossover(mut self, crossover: usize) -> Self {
        self.crossover = crossover;
        self
    }

    /// The forced kernel family, or `"auto"` (BENCH `tuner` field).
    pub fn tuner_mode(&self) -> &'static str {
        self.force.map(TunerOverride::name).unwrap_or("auto")
    }

    /// Per-algorithm dispatch counts so far (BENCH `kernel_histogram`).
    pub fn kernel_histogram(&self) -> Vec<(&'static str, u64)> {
        self.counts.snapshot()
    }

    /// One dispatch decision. The env/explicit override pins depth-0
    /// (caller-facing) calls only: MSD bucket recursion returns to the
    /// auto tuner so a forced family still terminates through sensible
    /// sub-kernels. Stable call sites never get the unstable in-place
    /// parallel kernel.
    fn pick(&self, len: usize, level: usize, depth: usize, stable: bool) -> Algorithm {
        let p = TuningParams {
            len,
            level,
            depth,
            threads: self.pool.budget(),
            stable,
            crossover: self.crossover,
        };
        let algo = match self.force {
            Some(f) if depth == 0 => f.resolve(&p),
            _ => self.tuner.pick_algorithm(&p),
        };
        if stable && algo == Algorithm::Regions {
            Algorithm::MtOop
        } else {
            algo
        }
    }

    /// Sort bare keys confined (by the MSD recursion contract) to digit
    /// levels `0..=level`, dispatching through the tuner.
    fn sort_keys(&self, keys: &mut [u64], level: usize, depth: usize) {
        if keys.len() <= 1 {
            return;
        }
        let algo = self.pick(keys.len(), level, depth, false);
        self.counts.bump(algo);
        match algo {
            Algorithm::Comparative => keys.sort_unstable(),
            Algorithm::Lsb => lsd_sort_slice(keys, |&k| k),
            Algorithm::Ska => self.ska_sort_keys(keys, level, depth),
            Algorithm::MtOop => self.mt_oop(keys, |&k| k),
            Algorithm::Regions => self.regions_sort_keys(keys, depth),
        }
    }

    /// Stable pair sort under the same recursion contract.
    fn sort_pairs_slice(&self, pairs: &mut [(u64, u64)], level: usize, depth: usize) {
        if pairs.len() <= 1 {
            return;
        }
        let algo = self.pick(pairs.len(), level, depth, true);
        self.counts.bump(algo);
        match algo {
            Algorithm::Comparative => pairs.sort_by_key(|p| p.0),
            Algorithm::Lsb => lsd_sort_slice(pairs, |p: &(u64, u64)| p.0),
            Algorithm::Ska => self.msd_pairs(pairs, level, depth),
            // `pick` sanitizes Regions away for stable call sites.
            Algorithm::MtOop | Algorithm::Regions => self.mt_oop(pairs, |p: &(u64, u64)| p.0),
        }
    }

    /// In-place American-flag MSD pass + per-bucket tuner recursion.
    /// Unstable (cycle chasing permutes equal keys), so keys only.
    fn ska_sort_keys(&self, keys: &mut [u64], level: usize, depth: usize) {
        let counts = flag_partition(keys, level);
        if level == 0 {
            return;
        }
        let mut rest = keys;
        for width in counts {
            let (bucket, tail) = std::mem::take(&mut rest).split_at_mut(width);
            rest = tail;
            if bucket.len() > 1 {
                self.sort_keys(bucket, level - 1, depth + 1);
            }
        }
    }

    /// Stable MSD pass for pairs: out-of-place scatter in input order
    /// (American-flag swapping would break the §8 tie-break), then
    /// per-bucket tuner recursion.
    fn msd_pairs(&self, pairs: &mut [(u64, u64)], level: usize, depth: usize) {
        let n = pairs.len();
        let mut counts = [0usize; BUCKETS];
        for p in pairs.iter() {
            counts[digit(p.0, level)] += 1;
        }
        let trivial = counts.iter().any(|&c| c == n);
        if !trivial {
            let mut sums = prefix_sums(&counts);
            let mut scratch = vec![(0u64, 0u64); n];
            for p in pairs.iter() {
                let d = digit(p.0, level);
                scratch[sums[d]] = *p;
                sums[d] += 1;
            }
            pairs.copy_from_slice(&scratch);
        }
        if level == 0 {
            return;
        }
        if trivial {
            // Every key shares this digit; the whole block continues one
            // level down as a single bucket.
            self.sort_pairs_slice(pairs, level - 1, depth + 1);
            return;
        }
        let mut rest = pairs;
        for width in counts {
            let (bucket, tail) = std::mem::take(&mut rest).split_at_mut(width);
            rest = tail;
            if bucket.len() > 1 {
                self.sort_pairs_slice(bucket, level - 1, depth + 1);
            }
        }
    }

    /// Parallel stable out-of-place sort: one sequential top-byte
    /// scatter carves ≤ 256 contiguous bucket ranges in scratch, the
    /// per-bucket LSD sorts tile across the shared pool, and the result
    /// copies back. Bucket boundaries and per-bucket outputs are
    /// data-determined, so the result is identical at any worker count —
    /// including zero extras, when the tiles just run inline.
    fn mt_oop<T: Copy + Default + Send, F: Fn(&T) -> u64 + Sync>(&self, items: &mut [T], key: F) {
        let n = items.len();
        let mut counts = [0usize; BUCKETS];
        for item in items.iter() {
            counts[digit(key(item), TOP_LEVEL)] += 1;
        }
        if counts.iter().any(|&c| c == n) {
            // One bucket holds everything: no split to parallelize over.
            lsd_sort_slice(items, key);
            return;
        }
        let mut sums = prefix_sums(&counts);
        let mut scratch = vec![T::default(); n];
        for item in items.iter() {
            let d = digit(key(item), TOP_LEVEL);
            scratch[sums[d]] = *item;
            sums[d] += 1;
        }
        let mut jobs: Vec<&mut [T]> = Vec::new();
        let mut rest = &mut scratch[..];
        for width in counts {
            let (bucket, tail) = std::mem::take(&mut rest).split_at_mut(width);
            rest = tail;
            if bucket.len() > 1 {
                jobs.push(bucket);
            }
        }
        self.pool.run_jobs(jobs, |bucket| lsd_sort_slice(bucket, &key));
        items.copy_from_slice(&scratch);
    }

    /// Parallel in-place keys-only sort (regions-sort shape): an
    /// in-place flag partition at the top byte, then the disjoint bucket
    /// slices recurse through the tuner across the shared pool.
    fn regions_sort_keys(&self, keys: &mut [u64], depth: usize) {
        let counts = flag_partition(keys, TOP_LEVEL);
        let mut jobs: Vec<&mut [u64]> = Vec::new();
        let mut rest = keys;
        for width in counts {
            let (bucket, tail) = std::mem::take(&mut rest).split_at_mut(width);
            rest = tail;
            if bucket.len() > 1 {
                jobs.push(bucket);
            }
        }
        self.pool
            .run_jobs(jobs, |bucket| self.sort_keys(bucket, TOP_LEVEL - 1, depth + 1));
    }
}

#[inline]
fn digit(key: u64, level: usize) -> usize {
    ((key >> (RADIX_BITS * level as u32)) & (BUCKETS as u64 - 1)) as usize
}

/// Per-digit histograms for all eight levels in one pass over the data.
fn histograms<T, F: Fn(&T) -> u64>(items: &[T], key: F) -> Vec<[usize; BUCKETS]> {
    let mut counts = vec![[0usize; BUCKETS]; LEVELS];
    for item in items {
        let k = key(item);
        for (level, c) in counts.iter_mut().enumerate() {
            c[digit(k, level)] += 1;
        }
    }
    counts
}

/// Exclusive prefix sums of one digit histogram.
fn prefix_sums(counts: &[usize; BUCKETS]) -> [usize; BUCKETS] {
    let mut sums = [0usize; BUCKETS];
    let mut total = 0;
    for (s, &c) in sums.iter_mut().zip(counts.iter()) {
        *s = total;
        total += c;
    }
    sums
}

/// One stable scatter of `src` into `dst` at `level`.
fn scatter_level<T: Copy, F: Fn(&T) -> u64>(
    src: &[T],
    dst: &mut [T],
    level: usize,
    sums: &mut [usize; BUCKETS],
    key: &F,
) {
    for item in src {
        let d = digit(key(item), level);
        dst[sums[d]] = *item;
        sums[d] += 1;
    }
}

/// LSD radix sort of a slice by `key`, stable, skipping trivial digits.
/// Ping-pongs between the slice and one scratch buffer; copies back if
/// the final pass landed in scratch.
fn lsd_sort_slice<T: Copy + Default, F: Fn(&T) -> u64>(items: &mut [T], key: F) {
    let n = items.len();
    if n <= 1 {
        return;
    }
    let counts = histograms(items, &key);
    let mut scratch: Vec<T> = Vec::new();
    let mut in_scratch = false;
    for (level, c) in counts.iter().enumerate() {
        if c.iter().any(|&b| b == n) {
            continue; // every key shares this digit: the pass is a no-op
        }
        if scratch.is_empty() {
            scratch.resize(n, T::default());
        }
        let mut sums = prefix_sums(c);
        if in_scratch {
            scatter_level(&scratch, items, level, &mut sums, &key);
        } else {
            scatter_level(items, &mut scratch, level, &mut sums, &key);
        }
        in_scratch = !in_scratch;
    }
    if in_scratch {
        items.copy_from_slice(&scratch);
    }
}

/// In-place American-flag partition of `keys` on digit `level` using
/// cycle chasing: hold one key in hand, deposit it at its bucket's head
/// while picking up the displaced key, until the cycle closes. Returns
/// the bucket widths (callers derive the sub-ranges). Unstable.
fn flag_partition(keys: &mut [u64], level: usize) -> [usize; BUCKETS] {
    let mut counts = [0usize; BUCKETS];
    for &k in keys.iter() {
        counts[digit(k, level)] += 1;
    }
    let starts = prefix_sums(&counts);
    let mut heads = starts;
    let mut ends = [0usize; BUCKETS];
    for (e, (&s, &c)) in ends.iter_mut().zip(starts.iter().zip(counts.iter())) {
        *e = s + c;
    }
    for b in 0..BUCKETS {
        while heads[b] < ends[b] {
            let mut k = keys[heads[b]];
            let mut d = digit(k, level);
            while d != b {
                std::mem::swap(&mut k, &mut keys[heads[d]]);
                heads[d] += 1;
                d = digit(k, level);
            }
            keys[heads[b]] = k;
            heads[b] += 1;
        }
    }
    counts
}

/// Bucket of `key` against sorted `pivots`: `|{i : pivots[i] <= key}|`.
/// Branchless linear scan for short pivot lists (NanoSort's b-1 = 15),
/// binary search for long ones (MilliSort's cores-1).
#[inline]
fn bucket_of(key: u64, pivots: &[u64]) -> usize {
    if pivots.len() <= LINEAR_SCAN_PIVOTS {
        pivots.iter().map(|&p| (p <= key) as usize).sum()
    } else {
        pivots.partition_point(|&p| p <= key)
    }
}

/// One tag+count pass, then scatter into exact-capacity bucket buffers.
fn partition_by<T: Copy, F: Fn(&T) -> u64>(
    items: &[T],
    pivots: &[u64],
    key: F,
) -> Vec<Vec<T>> {
    debug_assert!(pivots.windows(2).all(|w| w[0] <= w[1]));
    let b = pivots.len() + 1;
    let mut tags: Vec<u32> = Vec::with_capacity(items.len());
    let mut counts = vec![0usize; b];
    for item in items {
        let t = bucket_of(key(item), pivots);
        tags.push(t as u32);
        counts[t] += 1;
    }
    let mut out: Vec<Vec<T>> = counts.iter().map(|&c| Vec::with_capacity(c)).collect();
    for (item, &t) in items.iter().zip(&tags) {
        out[t as usize].push(*item);
    }
    out
}

impl LocalCompute for RadixCompute {
    fn sort(&self, keys: &mut Vec<u64>) {
        self.sort_keys(keys, TOP_LEVEL, 0);
    }

    fn sort_pairs(&self, pairs: &mut Vec<(u64, u64)>) {
        self.sort_pairs_slice(pairs, TOP_LEVEL, 0);
    }

    fn min(&self, vals: &[u64]) -> Option<u64> {
        NativeCompute.min(vals)
    }

    fn bucketize(&self, keys: &[u64], pivots: &[u64]) -> Vec<u32> {
        debug_assert!(pivots.windows(2).all(|w| w[0] <= w[1]));
        keys.iter().map(|&k| bucket_of(k, pivots) as u32).collect()
    }

    fn partition(&self, keys: &[u64], pivots: &[u64]) -> Vec<Vec<u64>> {
        partition_by(keys, pivots, |&k| k)
    }

    fn partition_pairs(&self, pairs: &[(u64, u64)], pivots: &[u64]) -> Vec<Vec<(u64, u64)>> {
        partition_by(pairs, pivots, |p| p.0)
    }

    fn median_combine(&self, rows: &[&[u64]]) -> Vec<u64> {
        NativeCompute.median_combine(rows)
    }

    fn name(&self) -> &'static str {
        "radix"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::test_support::rand_keys;

    /// Force the LSD path regardless of the tuner.
    fn lsd_only(mut keys: Vec<u64>) -> Vec<u64> {
        lsd_sort_slice(&mut keys, |&k| k);
        keys
    }

    #[test]
    fn lsd_sorts_across_sizes_and_patterns() {
        for n in [0usize, 1, 2, 3, DEFAULT_CROSSOVER - 1, DEFAULT_CROSSOVER, 1000, 4096] {
            let keys = rand_keys(n as u64 + 7, n);
            let mut expect = keys.clone();
            expect.sort_unstable();
            assert_eq!(lsd_only(keys), expect, "n={n}");
        }
        // Already-sorted, reversed, all-equal, and boundary values.
        let sorted: Vec<u64> = (0..500).collect();
        assert_eq!(lsd_only(sorted.clone()), sorted);
        let rev: Vec<u64> = (0..500).rev().collect();
        assert_eq!(lsd_only(rev), sorted);
        assert_eq!(lsd_only(vec![9; 300]), vec![9; 300]);
        let edges = vec![u64::MAX, 0, u64::MAX - 1, 1, u64::MAX, 1 << 63];
        let mut expect = edges.clone();
        expect.sort_unstable();
        assert_eq!(lsd_only(edges), expect);
    }

    #[test]
    fn trivial_digit_skip_is_exercised_and_exact() {
        // Keys confined to one byte of spread: 7 of 8 digit passes are
        // skipped, output must still be fully sorted.
        let keys: Vec<u64> = rand_keys(3, 600)
            .into_iter()
            .map(|k| 0xAB00_0000_0000_0000 | (k & 0xFF) << 8)
            .collect();
        let mut expect = keys.clone();
        expect.sort_unstable();
        assert_eq!(lsd_only(keys), expect);
    }

    #[test]
    fn flag_partition_groups_and_preserves_the_multiset() {
        for (seed, n) in [(21u64, 1usize), (22, 255), (23, 4096)] {
            let mut keys = rand_keys(seed, n);
            let mut expect = keys.clone();
            expect.sort_unstable();
            let counts = flag_partition(&mut keys, TOP_LEVEL);
            assert_eq!(counts.iter().sum::<usize>(), n);
            // Digits ascend across the slice and widths match the counts.
            let mut at = 0;
            for (b, &c) in counts.iter().enumerate() {
                for &k in &keys[at..at + c] {
                    assert_eq!(digit(k, TOP_LEVEL), b);
                }
                at += c;
            }
            keys.sort_unstable();
            assert_eq!(keys, expect, "partition must be a permutation");
        }
    }

    #[test]
    fn sort_pairs_is_stable_above_and_below_the_crossover() {
        let rc = RadixCompute::default();
        for n in [10usize, DEFAULT_CROSSOVER, 800] {
            // Few distinct keys so every key value has many ties; the
            // payload records input position.
            let mut pairs: Vec<(u64, u64)> = rand_keys(n as u64, n)
                .into_iter()
                .enumerate()
                .map(|(i, k)| (k % 7, i as u64))
                .collect();
            let mut expect = pairs.clone();
            expect.sort_by_key(|p| p.0);
            rc.sort_pairs(&mut pairs);
            assert_eq!(pairs, expect, "n={n}");
        }
    }

    #[test]
    fn every_forced_family_sorts_identically() {
        let oracle = NativeCompute;
        for force in TunerOverride::ALL {
            for budget in [1usize, 4] {
                let rc = RadixCompute::forced(Some(force), Arc::new(WorkerPool::new(budget)));
                let mut keys = rand_keys(0xF0 + budget as u64, 10_000);
                let mut expect = keys.clone();
                oracle.sort(&mut expect);
                rc.sort(&mut keys);
                assert_eq!(keys, expect, "force={force:?} budget={budget}");
            }
        }
    }

    #[test]
    fn crossover_is_tunable_and_exact_at_the_boundary() {
        // A crossover of 10 flips the kernel between 9 and 10 elements;
        // outputs must be byte-identical on both sides regardless.
        let rc = RadixCompute::default().with_crossover(10);
        for n in [9usize, 10, 11] {
            let mut keys = rand_keys(n as u64, n);
            let mut expect = keys.clone();
            expect.sort_unstable();
            rc.sort(&mut keys);
            assert_eq!(keys, expect, "n={n}");
        }
        // The dispatcher hands the tuned value to the policy.
        assert_eq!(rc.pick(9, TOP_LEVEL, 0, false), Algorithm::Comparative);
        assert_eq!(rc.pick(10, TOP_LEVEL, 0, false), Algorithm::Lsb);
    }

    #[test]
    fn kernel_histogram_records_dispatches() {
        let rc = RadixCompute::forced(Some(TunerOverride::Lsb), Arc::new(WorkerPool::new(1)));
        let mut keys = rand_keys(77, 512);
        rc.sort(&mut keys);
        let hist = rc.kernel_histogram();
        assert_eq!(hist.iter().find(|(k, _)| *k == "lsb").unwrap().1, 1);
        assert_eq!(rc.tuner_mode(), "lsb");
        assert_eq!(RadixCompute::default().tuner_mode(), "auto");
    }

    #[test]
    fn bucket_of_matches_partition_point_on_both_paths() {
        let mut short = rand_keys(11, LINEAR_SCAN_PIVOTS);
        short.sort_unstable();
        let mut long = rand_keys(12, LINEAR_SCAN_PIVOTS + 1);
        long.sort_unstable();
        for pivots in [&short, &long] {
            for &k in rand_keys(13, 200).iter().chain(pivots.iter()) {
                assert_eq!(
                    bucket_of(k, pivots),
                    pivots.partition_point(|&p| p <= k),
                    "k={k}"
                );
            }
        }
    }

    #[test]
    fn partition_scatters_in_input_order_with_exact_sizes() {
        let rc = RadixCompute::default();
        let pivots = vec![100u64, 200, 300];
        let keys = rand_keys(5, 400);
        let parts = rc.partition(&keys, &pivots);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts.iter().map(Vec::len).sum::<usize>(), keys.len());
        // Per-bucket subsequences appear in input order.
        for (b, part) in parts.iter().enumerate() {
            let expect: Vec<u64> = keys
                .iter()
                .copied()
                .filter(|&k| bucket_of(k, &pivots) == b)
                .collect();
            assert_eq!(part, &expect, "bucket {b}");
        }
    }
}
