//! Pure-Rust data plane: the oracle implementation of [`LocalCompute`].

use super::LocalCompute;

/// Straightforward Rust implementations (pdqsort, linear scans).
#[derive(Debug, Clone, Default)]
pub struct NativeCompute;

impl LocalCompute for NativeCompute {
    fn sort(&self, keys: &mut Vec<u64>) {
        keys.sort_unstable();
    }

    fn min(&self, vals: &[u64]) -> u64 {
        *vals.iter().min().expect("min of empty slice")
    }

    fn bucketize(&self, keys: &[u64], pivots: &[u64]) -> Vec<u32> {
        debug_assert!(pivots.windows(2).all(|w| w[0] <= w[1]));
        keys.iter()
            .map(|&k| pivots.partition_point(|&p| p <= k) as u32)
            .collect()
    }

    fn median_combine(&self, rows: &[Vec<u64>]) -> Vec<u64> {
        let m = rows.len();
        assert!(m > 0);
        let p = rows[0].len();
        let mut out = Vec::with_capacity(p);
        let mut col = Vec::with_capacity(m);
        for j in 0..p {
            col.clear();
            col.extend(rows.iter().map(|r| r[j]));
            col.sort_unstable();
            out.push(col[(m - 1) / 2]); // lower median
        }
        out
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::test_support::rand_keys;

    #[test]
    fn sort_sorts() {
        let nc = NativeCompute;
        let mut keys = rand_keys(1, 100);
        let mut expect = keys.clone();
        expect.sort_unstable();
        nc.sort(&mut keys);
        assert_eq!(keys, expect);
    }

    #[test]
    fn bucketize_matches_definition() {
        let nc = NativeCompute;
        let pivots = vec![10u64, 20, 30];
        let keys = vec![0u64, 10, 15, 20, 30, 31, 9, 29];
        // key == pivot goes right (side='right' in the jnp oracle).
        assert_eq!(nc.bucketize(&keys, &pivots), vec![0, 1, 1, 2, 3, 3, 0, 2]);
    }

    #[test]
    fn median_combine_lower_median() {
        let nc = NativeCompute;
        let rows = vec![vec![1u64, 100], vec![2, 200], vec![3, 300], vec![4, 400]];
        // even m: lower median = element (m-1)/2 = index 1
        assert_eq!(nc.median_combine(&rows), vec![2, 200]);
        let rows5 = vec![vec![5u64], vec![1], vec![3], vec![2], vec![4]];
        assert_eq!(nc.median_combine(&rows5), vec![3]);
    }

    #[test]
    fn min_works() {
        let nc = NativeCompute;
        assert_eq!(nc.min(&[5, 2, 9]), 2);
        assert_eq!(nc.min(&[7]), 7);
    }
}
