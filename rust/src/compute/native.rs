//! Pure-Rust data plane: the oracle implementation of [`LocalCompute`].

use super::LocalCompute;

/// Straightforward Rust implementations (pdqsort, linear scans). The
/// fused kernels come from the trait defaults, which are written in
/// terms of these base operations — so this backend *is* the oracle
/// semantics the radix and XLA planes are differentially tested against.
#[derive(Debug, Clone, Default)]
pub struct NativeCompute;

impl LocalCompute for NativeCompute {
    fn sort(&self, keys: &mut Vec<u64>) {
        keys.sort_unstable();
    }

    fn min(&self, vals: &[u64]) -> Option<u64> {
        vals.iter().copied().min()
    }

    fn bucketize(&self, keys: &[u64], pivots: &[u64]) -> Vec<u32> {
        debug_assert!(pivots.windows(2).all(|w| w[0] <= w[1]));
        keys.iter()
            .map(|&k| pivots.partition_point(|&p| p <= k) as u32)
            .collect()
    }

    fn median_combine(&self, rows: &[&[u64]]) -> Vec<u64> {
        let m = rows.len();
        assert!(m > 0, "median_combine of zero rows");
        let p = rows[0].len();
        // Ragged rows would silently index out of bounds mid-column (or
        // truncate, depending on iteration order); fail loudly instead.
        assert!(
            rows.iter().all(|r| r.len() == p),
            "median_combine rows must share one length (got {:?})",
            rows.iter().map(|r| r.len()).collect::<Vec<_>>()
        );
        let mut out = Vec::with_capacity(p);
        let mut col = Vec::with_capacity(m);
        for j in 0..p {
            col.clear();
            col.extend(rows.iter().map(|r| r[j]));
            col.sort_unstable();
            out.push(col[(m - 1) / 2]); // lower median
        }
        out
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::test_support::rand_keys;

    #[test]
    fn sort_sorts() {
        let nc = NativeCompute;
        let mut keys = rand_keys(1, 100);
        let mut expect = keys.clone();
        expect.sort_unstable();
        nc.sort(&mut keys);
        assert_eq!(keys, expect);
    }

    #[test]
    fn bucketize_matches_definition() {
        let nc = NativeCompute;
        let pivots = vec![10u64, 20, 30];
        let keys = vec![0u64, 10, 15, 20, 30, 31, 9, 29];
        // key == pivot goes right (side='right' in the jnp oracle).
        assert_eq!(nc.bucketize(&keys, &pivots), vec![0, 1, 1, 2, 3, 3, 0, 2]);
    }

    #[test]
    fn median_combine_lower_median() {
        let nc = NativeCompute;
        let rows: [&[u64]; 4] = [&[1, 100], &[2, 200], &[3, 300], &[4, 400]];
        // even m: lower median = element (m-1)/2 = index 1
        assert_eq!(nc.median_combine(&rows), vec![2, 200]);
        let rows5: [&[u64]; 5] = [&[5], &[1], &[3], &[2], &[4]];
        assert_eq!(nc.median_combine(&rows5), vec![3]);
    }

    /// Regression: ragged rows used to panic deep inside the column loop
    /// with a bare index error; the precondition is now checked up front
    /// with a message naming the row lengths.
    #[test]
    #[should_panic(expected = "median_combine rows must share one length")]
    fn median_combine_rejects_ragged_rows() {
        NativeCompute.median_combine(&[&[1u64, 2, 3], &[4, 5]]);
    }

    #[test]
    #[should_panic(expected = "median_combine of zero rows")]
    fn median_combine_rejects_zero_rows() {
        NativeCompute.median_combine(&[]);
    }

    /// Regression: `min` used to `expect` on an empty slice; it now
    /// reports emptiness through the type instead of panicking.
    #[test]
    fn min_is_empty_safe() {
        let nc = NativeCompute;
        assert_eq!(nc.min(&[5, 2, 9]), Some(2));
        assert_eq!(nc.min(&[7]), Some(7));
        assert_eq!(nc.min(&[]), None);
    }

    /// Trait-default fused kernels express the oracle semantics.
    #[test]
    fn default_sort_pairs_is_stable_by_key() {
        let nc = NativeCompute;
        let mut pairs = vec![(3u64, 0u64), (1, 1), (3, 2), (1, 3), (2, 4), (3, 5)];
        nc.sort_pairs(&mut pairs);
        assert_eq!(pairs, vec![(1, 1), (1, 3), (2, 4), (3, 0), (3, 2), (3, 5)]);
    }

    #[test]
    fn default_partition_matches_bucketize_with_input_order_ties() {
        let nc = NativeCompute;
        let pivots = vec![10u64, 20];
        let keys = vec![25u64, 5, 10, 15, 9, 20, 30];
        let parts = nc.partition(&keys, &pivots);
        assert_eq!(parts, vec![vec![5, 9], vec![10, 15], vec![25, 20, 30]]);
        // Pair form: payloads ride along, same bucket order.
        let pairs: Vec<(u64, u64)> =
            keys.iter().enumerate().map(|(i, &k)| (k, i as u64)).collect();
        let pp = nc.partition_pairs(&pairs, &pivots);
        assert_eq!(pp[0], vec![(5, 1), (9, 4)]);
        assert_eq!(pp[1], vec![(10, 2), (15, 3)]);
        assert_eq!(pp[2], vec![(25, 0), (20, 5), (30, 6)]);
    }
}
