//! The three-layer data plane: every operation executes an AOT-compiled
//! XLA artifact (Pallas kernel → JAX → HLO text → PJRT).
//!
//! Artifacts are compiled for a fixed menu of static shapes (see
//! `python/compile/aot.py`); inputs are padded up to the nearest variant
//! with `u64::MAX` sentinels (which sort to the end / bucketize out of
//! range and are discarded). Shapes with no compiled variant fall back to
//! [`NativeCompute`] and are counted, so a report can state exactly how
//! much of the data plane ran through XLA.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::runtime::XlaEngine;

use super::{LocalCompute, NativeCompute};

/// Sentinel used to pad blocks up to a compiled shape.
const PAD: u64 = u64::MAX;

/// b=1 sort variants compiled by aot.py, ascending.
const SORT_SIZES: [usize; 5] = [16, 32, 64, 128, 256];
/// b=1 merge_min variants.
const MIN_SIZES: [usize; 7] = [2, 4, 8, 16, 32, 64, 128];
/// b=1 bucketize variants per pivot count.
const BUCKETIZE_SIZES_P15: [usize; 3] = [16, 32, 64];
const BUCKETIZE_SIZES_P7: [usize; 1] = [32];
const BUCKETIZE_SIZES_P3: [usize; 1] = [32];
/// median_combine variants (m, p).
const MEDIAN_SHAPES: [(usize, usize); 8] =
    [(2, 15), (4, 15), (8, 15), (16, 15), (4, 7), (8, 7), (8, 3), (4, 3)];

/// Call counters for transparency in reports.
#[derive(Debug, Default)]
pub struct XlaCounters {
    pub xla_calls: AtomicU64,
    pub native_fallbacks: AtomicU64,
}

/// XLA-backed [`LocalCompute`].
pub struct XlaCompute {
    engine: Arc<XlaEngine>,
    native: NativeCompute,
    pub counters: XlaCounters,
}

impl XlaCompute {
    pub fn new(engine: Arc<XlaEngine>) -> Self {
        XlaCompute { engine, native: NativeCompute, counters: XlaCounters::default() }
    }

    /// Open the default artifacts directory.
    pub fn open_default() -> Result<Self> {
        Ok(Self::new(Arc::new(XlaEngine::open_default()?)))
    }

    pub fn engine(&self) -> &Arc<XlaEngine> {
        &self.engine
    }

    fn bump_xla(&self) {
        self.counters.xla_calls.fetch_add(1, Ordering::Relaxed);
    }
    fn bump_fallback(&self) {
        self.counters.native_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Fraction of data-plane calls that executed through XLA.
    pub fn xla_fraction(&self) -> f64 {
        let x = self.counters.xla_calls.load(Ordering::Relaxed) as f64;
        let f = self.counters.native_fallbacks.load(Ordering::Relaxed) as f64;
        if x + f == 0.0 {
            1.0
        } else {
            x / (x + f)
        }
    }

    fn sort_padded(&self, keys: &[u64], variant: usize) -> Result<Vec<u64>> {
        debug_assert!(keys.len() <= variant);
        debug_assert!(keys.iter().all(|&k| k < PAD), "keys must be < u64::MAX");
        let mut buf = keys.to_vec();
        buf.resize(variant, PAD);
        let art = self.engine.load(&format!("sort_block_b1_n{variant}"))?;
        let mut out = art.run_u64(&[&buf])?;
        let mut sorted = out.swap_remove(0);
        sorted.truncate(keys.len());
        Ok(sorted)
    }

    fn min_padded(&self, vals: &[u64], variant: usize) -> Result<u64> {
        let mut buf = vals.to_vec();
        buf.resize(variant, PAD);
        let art = self.engine.load(&format!("merge_min_block_b1_n{variant}"))?;
        let out = art.run_u64(&[&buf])?;
        Ok(out[0][0])
    }

    fn bucketize_padded(
        &self,
        keys: &[u64],
        pivots: &[u64],
        variant: usize,
    ) -> Result<Vec<u32>> {
        let p = pivots.len();
        let mut buf = keys.to_vec();
        buf.resize(variant, PAD);
        let art = self
            .engine
            .load(&format!("bucketize_block_b1_n{variant}_p{p}"))?;
        let out = art.run_mixed(&[&buf, pivots])?;
        Ok(out[0].as_i32()[..keys.len()].iter().map(|&v| v as u32).collect())
    }
}

fn pick_variant(sizes: &[usize], n: usize) -> Option<usize> {
    sizes.iter().copied().find(|&s| s >= n)
}

impl LocalCompute for XlaCompute {
    fn sort(&self, keys: &mut Vec<u64>) {
        let n = keys.len();
        if n <= 1 {
            return;
        }
        if let Some(variant) = pick_variant(&SORT_SIZES, n) {
            match self.sort_padded(keys, variant) {
                Ok(sorted) => {
                    *keys = sorted;
                    self.bump_xla();
                    return;
                }
                Err(e) => panic!("xla sort failed: {e:#}"),
            }
        }
        // Oversize block: sort 256-key runs through the kernel, then do a
        // k-way merge natively (the hot inner loops still ran through XLA).
        let max = *SORT_SIZES.last().unwrap();
        let mut runs: Vec<Vec<u64>> = Vec::new();
        for chunk in keys.chunks(max) {
            runs.push(self.sort_padded(chunk, max).expect("xla sort chunk"));
            self.bump_xla();
        }
        let mut merged = Vec::with_capacity(n);
        let mut cursors = vec![0usize; runs.len()];
        for _ in 0..n {
            let (ri, _) = runs
                .iter()
                .enumerate()
                .filter(|(i, r)| cursors[*i] < r.len())
                .min_by_key(|(i, r)| r[cursors[*i]])
                .expect("non-empty run");
            merged.push(runs[ri][cursors[ri]]);
            cursors[ri] += 1;
        }
        *keys = merged;
    }

    /// The fused pair sort still routes the heavy kernel through XLA:
    /// sort the keys via the compiled artifact, then reattach each
    /// payload to its key's equal range in input order — the §8 stable
    /// tie-break, byte-identical to the oracle. (Inheriting the trait
    /// default would silently demote NanoSort's per-level and final
    /// sorts to host-side std sorts on this plane.)
    fn sort_pairs(&self, pairs: &mut Vec<(u64, u64)>) {
        if pairs.len() <= 1 {
            return;
        }
        let mut keys: Vec<u64> = pairs.iter().map(|p| p.0).collect();
        self.sort(&mut keys);
        let mut out: Vec<(u64, u64)> = keys.iter().map(|&k| (k, 0)).collect();
        // Next free slot per key value; first occurrence starts at the
        // equal range's beginning, later duplicates fill forward.
        let mut cursor: HashMap<u64, usize> = HashMap::new();
        for &(k, payload) in pairs.iter() {
            let slot = cursor
                .entry(k)
                .or_insert_with(|| keys.partition_point(|&x| x < k));
            out[*slot].1 = payload;
            *slot += 1;
        }
        *pairs = out;
    }

    fn min(&self, vals: &[u64]) -> Option<u64> {
        if vals.is_empty() {
            return None;
        }
        if vals.len() == 1 {
            return Some(vals[0]);
        }
        let max = *MIN_SIZES.last().unwrap();
        if let Some(variant) = pick_variant(&MIN_SIZES, vals.len()) {
            self.bump_xla();
            return Some(self.min_padded(vals, variant).expect("xla min"));
        }
        // Chunk, reduce each through the kernel, combine the chunk minima.
        let minima: Vec<u64> = vals
            .chunks(max)
            .map(|c| {
                self.bump_xla();
                self.min_padded(c, max).expect("xla min chunk")
            })
            .collect();
        self.min(&minima)
    }

    fn bucketize(&self, keys: &[u64], pivots: &[u64]) -> Vec<u32> {
        let sizes: &[usize] = match pivots.len() {
            15 => &BUCKETIZE_SIZES_P15,
            7 => &BUCKETIZE_SIZES_P7,
            3 => &BUCKETIZE_SIZES_P3,
            _ => {
                self.bump_fallback();
                return self.native.bucketize(keys, pivots);
            }
        };
        let max = *sizes.last().unwrap();
        let mut out = Vec::with_capacity(keys.len());
        for chunk in keys.chunks(max) {
            let variant = pick_variant(sizes, chunk.len()).unwrap();
            out.extend(self.bucketize_padded(chunk, pivots, variant).expect("xla bucketize"));
            self.bump_xla();
        }
        out
    }

    fn median_combine(&self, rows: &[&[u64]]) -> Vec<u64> {
        let m = rows.len();
        let p = rows.first().map(|r| r.len()).unwrap_or(0);
        if !MEDIAN_SHAPES.contains(&(m, p)) {
            self.bump_fallback();
            return self.native.median_combine(rows);
        }
        let flat: Vec<u64> = rows.iter().flat_map(|r| r.iter()).copied().collect();
        let art = self
            .engine
            .load(&format!("median_combine_m{m}_p{p}"))
            .expect("median artifact");
        let out = art.run_u64(&[&flat]).expect("xla median_combine");
        self.bump_xla();
        out.into_iter().next().unwrap()
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::test_support::rand_keys;

    fn engine_or_skip() -> Option<XlaCompute> {
        match XlaCompute::open_default() {
            Ok(x) => Some(x),
            Err(e) => {
                eprintln!("skipping XLA tests (artifacts not built?): {e:#}");
                None
            }
        }
    }

    #[test]
    fn xla_sort_matches_native() {
        let Some(x) = engine_or_skip() else { return };
        let native = NativeCompute;
        for n in [1usize, 2, 5, 16, 17, 40, 64, 100, 256, 300, 700] {
            let mut a = rand_keys(n as u64, n);
            let mut b = a.clone();
            x.sort(&mut a);
            native.sort(&mut b);
            assert_eq!(a, b, "n={n}");
        }
        assert!(x.xla_fraction() > 0.99);
    }

    /// The pair sort must match the oracle *including* the stable
    /// equal-key tie-break (keys folded to a tiny range so every block
    /// is duplicate-heavy), while still running the sort through XLA.
    #[test]
    fn xla_sort_pairs_matches_native_stably() {
        let Some(x) = engine_or_skip() else { return };
        for n in [0usize, 1, 2, 5, 40, 64, 300] {
            let pairs: Vec<(u64, u64)> = rand_keys(n as u64 + 3, n)
                .into_iter()
                .enumerate()
                .map(|(i, k)| (k % 13, i as u64))
                .collect();
            let mut a = pairs.clone();
            let mut b = pairs;
            NativeCompute.sort_pairs(&mut a);
            x.sort_pairs(&mut b);
            assert_eq!(a, b, "n={n}");
        }
        assert!(x.xla_fraction() > 0.9, "pair sorts must route through the XLA kernel");
    }

    #[test]
    fn xla_min_matches_native() {
        let Some(x) = engine_or_skip() else { return };
        for n in [1usize, 2, 3, 8, 100, 129, 400] {
            let vals = rand_keys(7 + n as u64, n);
            assert_eq!(x.min(&vals), NativeCompute.min(&vals), "n={n}");
        }
        assert_eq!(x.min(&[]), None, "empty input is None, not a panic");
    }

    #[test]
    fn xla_bucketize_matches_native() {
        let Some(x) = engine_or_skip() else { return };
        let native = NativeCompute;
        for &p in &[3usize, 7, 15] {
            let mut pivots = rand_keys(p as u64, p);
            pivots.sort_unstable();
            for n in [1usize, 16, 33, 64, 65, 200] {
                let keys = rand_keys((n * p) as u64, n);
                assert_eq!(
                    x.bucketize(&keys, &pivots),
                    native.bucketize(&keys, &pivots),
                    "n={n} p={p}"
                );
            }
        }
    }

    #[test]
    fn xla_median_combine_matches_native() {
        let Some(x) = engine_or_skip() else { return };
        let native = NativeCompute;
        for &(m, p) in &MEDIAN_SHAPES {
            let owned: Vec<Vec<u64>> = (0..m)
                .map(|i| {
                    let mut r = rand_keys((m * p + i) as u64, p);
                    r.sort_unstable();
                    r
                })
                .collect();
            let rows: Vec<&[u64]> = owned.iter().map(|r| r.as_slice()).collect();
            assert_eq!(x.median_combine(&rows), native.median_combine(&rows), "m={m} p={p}");
        }
        // Un-compiled shape falls back to native.
        let rows: [&[u64]; 3] = [&[1, 2], &[3, 4], &[5, 6]];
        assert_eq!(x.median_combine(&rows), native.median_combine(&rows));
        assert!(x.counters.native_fallbacks.load(Ordering::Relaxed) >= 1);
    }
}
