//! Node-local data plane.
//!
//! The simulator's *timing* comes from [`crate::cpu::CoreModel`]; the
//! *data* transformations (keys actually moving and getting sorted) go
//! through a [`LocalCompute`] implementation:
//!
//! - [`NativeCompute`] — pure Rust; the oracle and the fast default for
//!   huge sweeps.
//! - [`XlaCompute`] — the paper-mandated three-layer path: each operation
//!   executes an AOT-compiled artifact (Pallas kernel → JAX → HLO text →
//!   PJRT) through [`crate::runtime::XlaEngine`]. Shapes are padded up to
//!   the nearest compiled variant with `u64::MAX` sentinels.
//!
//! Both implementations are cross-checked against each other in tests.
//!
//! Timing note: data-plane calls are timing-neutral — every operation's
//! cost is charged through [`crate::cpu::CoreModel`] by the node program,
//! and the engine scales those cycle charges per node for straggler cores
//! (the perturbation layer's slowdown factor, see [`crate::perturb`]), so
//! the same kernel output is produced regardless of which cores straggle.

mod native;
mod xla_compute;

pub use native::NativeCompute;
pub use xla_compute::XlaCompute;

/// Key-space data operations a simulated core performs.
///
/// Keys must be `< u64::MAX` (the padding sentinel); the GraySort
/// generator guarantees this.
///
/// `Send + Sync`: the parallel executor ([`crate::sim::exec`]) shares one
/// data plane across shard worker threads through `Arc`. The operations
/// are pure (same inputs → same outputs, no draw order), so concurrent
/// use cannot perturb results. [`NativeCompute`] is trivially
/// thread-safe. [`XlaCompute`] is *not* safe to drive from multiple
/// threads — the real PJRT CPU client is single-threaded — so the
/// scenario layer refuses to combine the XLA plane with a threaded
/// executor ([`crate::scenario::Scenario::threads`] must stay 1), and
/// the default build stubs the PJRT runtime out entirely (see
/// [`crate::runtime`]; the bound is satisfiable there because the stub
/// engine is never constructible).
pub trait LocalCompute: Send + Sync {
    /// Sort a block of keys ascending.
    fn sort(&self, keys: &mut Vec<u64>);

    /// Minimum of a non-empty slice.
    fn min(&self, vals: &[u64]) -> u64;

    /// Bucket index of each key against `pivots` (sorted, len = b-1):
    /// bucket = |{i : key >= pivots[i]}| in `[0, b)`.
    fn bucketize(&self, keys: &[u64], pivots: &[u64]) -> Vec<u32>;

    /// Element-wise lower median across rows (all rows same length).
    fn median_combine(&self, rows: &[Vec<u64>]) -> Vec<u64>;

    /// Implementation name (for reports).
    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod test_support {
    use crate::sim::SplitMix64;

    pub fn rand_keys(seed: u64, n: usize) -> Vec<u64> {
        let mut rng = SplitMix64::new(seed);
        (0..n).map(|_| rng.next_u64() % (u64::MAX - 1)).collect()
    }
}
