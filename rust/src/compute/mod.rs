//! Node-local data plane.
//!
//! The simulator's *timing* comes from [`crate::cpu::CoreModel`]; the
//! *data* transformations (keys actually moving and getting sorted) go
//! through a [`LocalCompute`] implementation:
//!
//! - [`NativeCompute`] — pure Rust comparison kernels; the
//!   differential-testing **oracle**. Every other backend is defined (and
//!   tested, `rust/tests/compute.rs`) to produce byte-identical outputs.
//! - [`RadixCompute`] — count-then-scatter radix kernels for the u64 key
//!   workloads (DESIGN.md §8); the default data plane. A [`Tuner`] picks
//!   the kernel per block (comparison / LSD / in-place MSD ska /
//!   parallel out-of-place or regions-style in-place, the last two
//!   tiling over the worker pool shared with the executor — see
//!   [`tuner`] and [`crate::pool`]). Identical outputs to the oracle by
//!   the tie-break contract below regardless of the kernel picked
//!   (`NANOSORT_TUNER` forces one family for A/B runs), measurably
//!   faster on large blocks.
//! - [`XlaCompute`] — the paper-mandated three-layer path: each operation
//!   executes an AOT-compiled artifact (Pallas kernel → JAX → HLO text →
//!   PJRT) through [`crate::runtime::XlaEngine`]. Shapes are padded up to
//!   the nearest compiled variant with `u64::MAX` sentinels.
//!
//! # Determinism contract (DESIGN.md §8)
//!
//! Backends are interchangeable *per digest byte*: a conformance run must
//! produce the same digest on every plane. That pins each operation to a
//! single canonical output, including tie-breaks:
//!
//! - [`LocalCompute::sort`] — ascending; u64 duplicates are
//!   indistinguishable, so any correct sort is canonical.
//! - [`LocalCompute::sort_pairs`] — ascending by key, **stable**: pairs
//!   with equal keys keep their input order. (Backend-independent, unlike
//!   an unstable argsort whose equal-key permutation is an implementation
//!   detail.)
//! - [`LocalCompute::partition`] / [`LocalCompute::partition_pairs`] —
//!   bucket of a key = `|{i : pivots[i] <= key}|`; within each bucket,
//!   elements keep their input order.
//!
//! Timing note: data-plane calls are timing-neutral — every operation's
//! cost is charged through [`crate::cpu::CoreModel`] by the node program,
//! and the engine scales those cycle charges per node for straggler cores
//! (the perturbation layer's slowdown factor, see [`crate::perturb`]), so
//! the same kernel output is produced regardless of which cores straggle.

mod native;
mod radix;
pub mod tuner;
mod xla_compute;

pub use native::NativeCompute;
pub use radix::RadixCompute;
pub use tuner::{
    Algorithm, StandardTuner, Tuner, TunerOverride, TuningParams, DEFAULT_CROSSOVER,
};
pub use xla_compute::XlaCompute;

/// Key-space data operations a simulated core performs.
///
/// Keys must be `< u64::MAX` (the padding sentinel); the GraySort
/// generator guarantees this.
///
/// The fused kernels ([`LocalCompute::sort_pairs`],
/// [`LocalCompute::partition`], [`LocalCompute::partition_pairs`]) have
/// default implementations expressing the oracle semantics in terms of
/// the base operations, so a backend only overrides them when it can do
/// better — [`XlaCompute`] inherits the defaults, [`RadixCompute`]
/// replaces them with single-pass count-then-scatter kernels.
///
/// `Send + Sync`: the parallel executor ([`crate::sim::exec`]) shares one
/// data plane across shard worker threads through `Arc`. The operations
/// are pure (same inputs → same outputs, no draw order), so concurrent
/// use cannot perturb results. [`NativeCompute`] and [`RadixCompute`] are
/// trivially thread-safe. [`XlaCompute`] is *not* safe to drive from
/// multiple threads — the real PJRT CPU client is single-threaded — so
/// the scenario layer refuses to combine the XLA plane with a threaded
/// executor ([`crate::scenario::Scenario::threads`] must stay 1), and
/// the default build stubs the PJRT runtime out entirely (see
/// [`crate::runtime`]; the bound is satisfiable there because the stub
/// engine is never constructible).
pub trait LocalCompute: Send + Sync {
    /// Sort a block of keys ascending.
    fn sort(&self, keys: &mut Vec<u64>);

    /// Minimum of a slice; `None` when the slice is empty.
    fn min(&self, vals: &[u64]) -> Option<u64>;

    /// Bucket index of each key against `pivots` (sorted, len = b-1):
    /// bucket = |{i : key >= pivots[i]}| in `[0, b)`.
    fn bucketize(&self, keys: &[u64], pivots: &[u64]) -> Vec<u32>;

    /// Element-wise lower median across rows. All rows must be the same
    /// length (callers aggregate fixed-width pivot vectors); ragged input
    /// is a caller bug and panics rather than silently truncating.
    /// Rows are borrowed slices so callers can aggregate in place —
    /// combining must not force a clone of every contribution (§Perf).
    fn median_combine(&self, rows: &[&[u64]]) -> Vec<u64>;

    /// Fused kernel: sort `(key, payload)` pairs ascending by key,
    /// **stable** (equal keys keep input order — the contract every
    /// backend must match, so origin permutations are digest-identical
    /// across planes). One pass over the pair array replaces the
    /// argsort-then-permute pattern.
    fn sort_pairs(&self, pairs: &mut Vec<(u64, u64)>) {
        pairs.sort_by_key(|p| p.0);
    }

    /// Fused kernel: route every key to its bucket in one counting pass +
    /// direct scatter. `out[b]` holds, in input order, the keys with
    /// bucket index `b` (same bucket definition as
    /// [`LocalCompute::bucketize`]); `out.len() == pivots.len() + 1`.
    fn partition(&self, keys: &[u64], pivots: &[u64]) -> Vec<Vec<u64>> {
        let tags = self.bucketize(keys, pivots);
        let mut out = vec![Vec::new(); pivots.len() + 1];
        for (&k, &t) in keys.iter().zip(&tags) {
            out[t as usize].push(k);
        }
        out
    }

    /// [`LocalCompute::partition`] over `(key, payload)` pairs (bucket by
    /// the key, the payload rides along; input order kept per bucket).
    fn partition_pairs(&self, pairs: &[(u64, u64)], pivots: &[u64]) -> Vec<Vec<(u64, u64)>> {
        let keys: Vec<u64> = pairs.iter().map(|p| p.0).collect();
        let tags = self.bucketize(&keys, pivots);
        let mut out = vec![Vec::new(); pivots.len() + 1];
        for (&pair, &t) in pairs.iter().zip(&tags) {
            out[t as usize].push(pair);
        }
        out
    }

    /// Implementation name (for reports).
    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod test_support {
    use crate::sim::SplitMix64;

    pub fn rand_keys(seed: u64, n: usize) -> Vec<u64> {
        let mut rng = SplitMix64::new(seed);
        (0..n).map(|_| rng.next_u64() % (u64::MAX - 1)).collect()
    }
}
