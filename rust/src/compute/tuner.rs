//! Kernel tuner for the radix data plane (rdst-style `pick_algorithm`).
//!
//! [`RadixCompute`](super::RadixCompute) no longer hardwires one kernel:
//! every `sort`/`sort_pairs` dispatch — top-level calls and per-bucket
//! MSD recursions alike — asks a [`Tuner`] which [`Algorithm`] to run,
//! given the block's [`TuningParams`] (length, digit level, recursion
//! depth, thread budget, stability requirement, comparison crossover).
//! The tuner picks *wall-clock*, never *results*: each algorithm
//! produces the §8-canonical output for its call site, so the choice is
//! digest-invisible by construction and differentially tested against
//! the `NativeCompute` oracle (`rust/tests/compute_tuner.rs`).
//!
//! The kernel families:
//!
//! - [`Algorithm::Comparative`] — std comparison sorts (`sort_unstable`
//!   for bare keys, stable `sort_by_key` for pairs). Wins below the
//!   crossover, where one counting pass costs more than pdqsort.
//! - [`Algorithm::Lsb`] — the LSD byte-radix kernel (stable, out of
//!   place, trivial-digit skip). The workhorse for mid-size blocks.
//! - [`Algorithm::Ska`] — MSD byte-radix: for keys an in-place
//!   American-flag (ska-style) cycle-chasing partition; for pairs a
//!   stable out-of-place scatter. Each bucket recurses *through the
//!   tuner* at `level - 1`, so small buckets finish on comparison sorts.
//! - [`Algorithm::MtOop`] — parallel stable out-of-place: one sequential
//!   top-byte scatter carves ≤ 256 contiguous bucket ranges, then the
//!   per-bucket LSD sorts tile across the shared worker pool
//!   ([`crate::pool::WorkerPool`]).
//! - [`Algorithm::Regions`] — parallel in-place (SPAA'19 regions-sort
//!   shape): an in-place flag partition at the top byte, then parallel
//!   per-bucket recursion over disjoint slices. Unstable → keys only;
//!   stable call sites degrade to [`Algorithm::MtOop`].
//!
//! `NANOSORT_TUNER=auto|comparative|lsb|ska|par` forces one family for
//! A/B runs ([`TunerOverride`], parsed once at plane construction;
//! malformed values panic — a silently ignored knob would invalidate a
//! measurement). Digests are tuner-invariant; only wall-clock moves.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

/// Default comparison-fallback crossover: below this many elements a
/// comparison sort beats any counting pass. Carried in [`TuningParams`]
/// (per-plane tunable, `RadixCompute::with_crossover`) rather than
/// hardwired in the kernels; boundary-tested at 95/96/97 keys.
pub const DEFAULT_CROSSOVER: usize = 96;

/// A concrete kernel family the dispatcher can run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// std comparison sort (stable for pairs, unstable for keys).
    Comparative,
    /// LSD byte radix, stable, out of place.
    Lsb,
    /// MSD byte radix (in-place American-flag for keys, stable scatter
    /// for pairs), per-bucket recursion through the tuner.
    Ska,
    /// Parallel stable out-of-place (top-byte scatter + pooled
    /// per-bucket LSD).
    MtOop,
    /// Parallel in-place regions-style (keys only; unstable).
    Regions,
}

impl Algorithm {
    pub const ALL: [Algorithm; 5] = [
        Algorithm::Comparative,
        Algorithm::Lsb,
        Algorithm::Ska,
        Algorithm::MtOop,
        Algorithm::Regions,
    ];

    /// Canonical name (BENCH `kernel_histogram` keys, bench rows).
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Comparative => "comparative",
            Algorithm::Lsb => "lsb",
            Algorithm::Ska => "ska",
            Algorithm::MtOop => "mt_oop",
            Algorithm::Regions => "regions",
        }
    }
}

/// Everything a [`Tuner`] may condition a kernel choice on.
#[derive(Debug, Clone, Copy)]
pub struct TuningParams {
    /// Elements in the block being dispatched.
    pub len: usize,
    /// Digit level the next MSD pass would partition on (7 = top byte,
    /// 0 = least significant).
    pub level: usize,
    /// Recursion depth: 0 for a caller-facing dispatch, +1 per MSD
    /// bucket recursion. Parallel kernels only engage at depth 0 — the
    /// sub-buckets they fan out already saturate the pool.
    pub depth: usize,
    /// The shared pool's total thread budget (1 = no parallel kernels).
    pub threads: usize,
    /// Whether this call site requires the §8 stable tie-break
    /// (`sort_pairs` does; bare-key `sort` does not — u64 duplicates are
    /// indistinguishable, so any correct sort is canonical).
    pub stable: bool,
    /// Comparison-fallback crossover for this plane
    /// ([`DEFAULT_CROSSOVER`] unless overridden).
    pub crossover: usize,
}

/// A kernel-selection policy. Implementations must be pure functions of
/// the params (no interior state): the same dispatch sequence must pick
/// the same kernels on every run, keeping wall-clock measurements
/// meaningful. Results never depend on the choice — every algorithm is
/// §8-canonical for the call sites that can pick it.
pub trait Tuner: Send + Sync {
    /// Pick the kernel family for one dispatch.
    fn pick_algorithm(&self, p: &TuningParams) -> Algorithm;

    /// Policy name (diagnostics).
    fn name(&self) -> &'static str;
}

/// The default policy: comparison below the crossover, parallel kernels
/// for large top-level blocks when the pool has threads to give, MSD
/// (ska) for large sequential blocks, LSD otherwise.
#[derive(Debug, Clone, Copy, Default)]
pub struct StandardTuner;

impl StandardTuner {
    /// Minimum block length for the sequential MSD (ska) kernel: below
    /// this the LSD kernel's single histogram pass wins; above it,
    /// top-byte partitioning confines keys to bucket sub-ranges whose
    /// recursive sorts skip most digit passes.
    pub const SKA_MIN: usize = 4096;
    /// Minimum top-level block length for the parallel kernels: the
    /// per-bucket tiles must amortize a pool hand-off each.
    pub const PAR_MIN: usize = 8192;
}

impl Tuner for StandardTuner {
    fn pick_algorithm(&self, p: &TuningParams) -> Algorithm {
        if p.len < p.crossover {
            return Algorithm::Comparative;
        }
        if p.depth == 0 && p.threads > 1 && p.len >= Self::PAR_MIN {
            return if p.stable { Algorithm::MtOop } else { Algorithm::Regions };
        }
        if p.len >= Self::SKA_MIN && p.level > 0 {
            // At level 0 an MSD partition *is* the last LSD pass with
            // nothing left to recurse into; Lsb handles it directly.
            return Algorithm::Ska;
        }
        Algorithm::Lsb
    }

    fn name(&self) -> &'static str {
        "standard"
    }
}

/// Forced kernel family (`NANOSORT_TUNER`), applied to depth-0
/// dispatches only — per-bucket recursion returns to the auto tuner, so
/// a forced MSD family still terminates through sensible sub-kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TunerOverride {
    Comparative,
    Lsb,
    Ska,
    /// The parallel family: resolves to [`Algorithm::MtOop`] for stable
    /// call sites, [`Algorithm::Regions`] otherwise.
    Par,
}

impl TunerOverride {
    pub const ALL: [TunerOverride; 4] = [
        TunerOverride::Comparative,
        TunerOverride::Lsb,
        TunerOverride::Ska,
        TunerOverride::Par,
    ];

    /// Parse an override value; `"auto"` means "no override" (`None`).
    pub fn parse(raw: &str) -> Result<Option<TunerOverride>> {
        Ok(match raw {
            "auto" => None,
            "comparative" => Some(TunerOverride::Comparative),
            "lsb" => Some(TunerOverride::Lsb),
            "ska" => Some(TunerOverride::Ska),
            "par" => Some(TunerOverride::Par),
            other => anyhow::bail!(
                "unknown tuner override {other:?} (known: auto|comparative|lsb|ska|par)"
            ),
        })
    }

    /// Read `NANOSORT_TUNER` (unset = auto). Malformed values panic,
    /// matching the strictness of `NANOSORT_WINDOW_BATCH`: an A/B knob
    /// that silently no-ops would invalidate the measurement it exists
    /// for. Read once at plane construction, never per dispatch.
    pub fn from_env() -> Option<TunerOverride> {
        match std::env::var("NANOSORT_TUNER") {
            Ok(raw) => TunerOverride::parse(&raw)
                .unwrap_or_else(|e| panic!("NANOSORT_TUNER: {e}")),
            Err(_) => None,
        }
    }

    /// The `--tuner`/env operand naming this family.
    pub fn name(self) -> &'static str {
        match self {
            TunerOverride::Comparative => "comparative",
            TunerOverride::Lsb => "lsb",
            TunerOverride::Ska => "ska",
            TunerOverride::Par => "par",
        }
    }

    /// Resolve the forced family to a concrete algorithm for one
    /// dispatch (the stability sanitizer for `Par`).
    pub fn resolve(self, p: &TuningParams) -> Algorithm {
        match self {
            TunerOverride::Comparative => Algorithm::Comparative,
            TunerOverride::Lsb => Algorithm::Lsb,
            TunerOverride::Ska => Algorithm::Ska,
            TunerOverride::Par => {
                if p.stable {
                    Algorithm::MtOop
                } else {
                    Algorithm::Regions
                }
            }
        }
    }
}

/// Per-algorithm dispatch counters (BENCH `kernel_histogram`): how often
/// each kernel family actually ran, including MSD bucket recursions.
/// Shared across plane clones; relaxed atomics — counts are telemetry,
/// never results.
#[derive(Debug, Default)]
pub struct KernelCounts {
    counts: [AtomicU64; 5],
}

impl KernelCounts {
    pub fn bump(&self, algo: Algorithm) {
        self.counts[algo as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// `(name, count)` per algorithm, in [`Algorithm::ALL`] order.
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        Algorithm::ALL
            .iter()
            .map(|&a| (a.name(), self.counts[a as usize].load(Ordering::Relaxed)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(len: usize) -> TuningParams {
        TuningParams {
            len,
            level: 7,
            depth: 0,
            threads: 1,
            stable: false,
            crossover: DEFAULT_CROSSOVER,
        }
    }

    /// Satellite bugfix gate: the crossover sits in `TuningParams`, and
    /// the boundary is exactly `len < crossover` — pinned at 95/96/97.
    #[test]
    fn crossover_boundary_is_exact_at_95_96_97() {
        let t = StandardTuner;
        assert_eq!(t.pick_algorithm(&params(95)), Algorithm::Comparative);
        assert_eq!(t.pick_algorithm(&params(96)), Algorithm::Lsb);
        assert_eq!(t.pick_algorithm(&params(97)), Algorithm::Lsb);
        // And it moves with the carried value, not a hidden constant.
        let custom = TuningParams { crossover: 10, ..params(9) };
        assert_eq!(t.pick_algorithm(&custom), Algorithm::Comparative);
        let custom = TuningParams { crossover: 10, ..params(10) };
        assert_eq!(t.pick_algorithm(&custom), Algorithm::Lsb);
    }

    #[test]
    fn standard_tuner_straddles_every_threshold() {
        let t = StandardTuner;
        // Sequential ladder: crossover → Lsb → Ska.
        assert_eq!(t.pick_algorithm(&params(StandardTuner::SKA_MIN - 1)), Algorithm::Lsb);
        assert_eq!(t.pick_algorithm(&params(StandardTuner::SKA_MIN)), Algorithm::Ska);
        // Parallel engages only at depth 0 with threads > 1 and len ≥ PAR_MIN.
        let par = TuningParams { threads: 4, ..params(StandardTuner::PAR_MIN) };
        assert_eq!(t.pick_algorithm(&par), Algorithm::Regions);
        let stable = TuningParams { stable: true, ..par };
        assert_eq!(t.pick_algorithm(&stable), Algorithm::MtOop);
        let small = TuningParams { threads: 4, ..params(StandardTuner::PAR_MIN - 1) };
        assert_eq!(t.pick_algorithm(&small), Algorithm::Ska);
        let deep = TuningParams { depth: 1, ..par };
        assert_eq!(t.pick_algorithm(&deep), Algorithm::Ska, "no nested parallel fan-out");
        // At level 0 there is nothing to recurse into: MSD degrades to LSD.
        let bottom = TuningParams { level: 0, ..params(StandardTuner::SKA_MIN) };
        assert_eq!(t.pick_algorithm(&bottom), Algorithm::Lsb);
    }

    #[test]
    fn override_parses_and_resolves() {
        assert_eq!(TunerOverride::parse("auto").unwrap(), None);
        for f in TunerOverride::ALL {
            assert_eq!(TunerOverride::parse(f.name()).unwrap(), Some(f));
        }
        assert!(TunerOverride::parse("bogo").is_err());
        // Par respects the stability requirement of the call site.
        assert_eq!(TunerOverride::Par.resolve(&params(10)), Algorithm::Regions);
        let stable = TuningParams { stable: true, ..params(10) };
        assert_eq!(TunerOverride::Par.resolve(&stable), Algorithm::MtOop);
        // The other overrides are unconditional.
        assert_eq!(TunerOverride::Ska.resolve(&params(1)), Algorithm::Ska);
    }

    #[test]
    fn kernel_counts_snapshot_in_canonical_order() {
        let counts = KernelCounts::default();
        counts.bump(Algorithm::Lsb);
        counts.bump(Algorithm::Lsb);
        counts.bump(Algorithm::Regions);
        let snap = counts.snapshot();
        assert_eq!(
            snap,
            vec![("comparative", 0), ("lsb", 2), ("ska", 0), ("mt_oop", 0), ("regions", 1)]
        );
    }
}
