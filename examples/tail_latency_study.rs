//! Tail-latency sensitivity study (paper Fig 14, extended).
//!
//! The paper's point: with tens of thousands of messages in flight, the
//! p99 latency *will* be experienced on the critical path — a 4,000 ns
//! p99 doubles NanoSort's runtime. This example sweeps both the injected
//! extra latency and the injection probability, and also compares how the
//! same tails hurt MilliSort (deeper dependency chains amplify tails).
//! All runs go through the unified `Scenario` API: the tail knobs are
//! environment (`NetConfig`) settings, not workload settings.
//!
//! ```sh
//! cargo run --release --example tail_latency_study
//! ```

use nanosort::algo::millisort::MilliSort;
use nanosort::algo::nanosort::NanoSort;
use nanosort::coordinator::Table;
use nanosort::net::NetConfig;
use nanosort::scenario::Scenario;

fn tail_net(prob: (u64, u64), extra_ns: u64) -> NetConfig {
    NetConfig { tail_prob: prob, tail_extra_ns: extra_ns, ..NetConfig::default() }
}

fn main() -> anyhow::Result<()> {
    // Part 1: Fig 14 proper — NanoSort, 256 cores, sweep p99 extra.
    let mut t1 = Table::new(
        "NanoSort runtime vs injected p99 extra latency (256 cores, 32 keys/core)",
        &["p99_extra_ns", "runtime_us", "slowdown", "tail_hits"],
    );
    let mut base = 0.0;
    for extra in [0u64, 250, 500, 1000, 2000, 4000, 8000] {
        let r = Scenario::new(NanoSort {
            keys_per_node: 32,
            shuffle_values: true,
            ..Default::default()
        })
        .nodes(256)
        .net(tail_net((1, 100), extra))
        .seed(3)
        .run()?;
        assert!(r.validation.ok());
        let us = r.runtime().as_us_f64();
        if extra == 0 {
            base = us;
        }
        t1.row(vec![
            extra.to_string(),
            format!("{us:.2}"),
            format!("{:.2}x", us / base),
            r.summary.net.tail_hits.to_string(),
        ]);
    }
    t1.note("paper: 4,000 ns p99 doubled runtime (26 µs -> 53 µs)");
    println!("{}", t1.render());

    // Part 2: injection probability sweep at fixed 4,000 ns.
    let mut t2 = Table::new(
        "Sensitivity to tail *probability* (4,000 ns extra)",
        &["tail_fraction", "runtime_us", "slowdown"],
    );
    for (num, den) in [(0u64, 100u64), (1, 1000), (1, 100), (5, 100), (10, 100)] {
        let r = Scenario::new(NanoSort {
            keys_per_node: 32,
            shuffle_values: true,
            ..Default::default()
        })
        .nodes(256)
        .net(tail_net((num, den), 4000))
        .seed(3)
        .run()?;
        let us = r.runtime().as_us_f64();
        t2.row(vec![
            format!("{:.3}", num as f64 / den as f64),
            format!("{us:.2}"),
            format!("{:.2}x", us / base),
        ]);
    }
    println!("{}", t2.render());

    // Part 3: the same tail vs MilliSort — longer dependency chains.
    let mut t3 = Table::new(
        "Same 1% tail injection vs MilliSort (128 cores, 4,096 keys)",
        &["p99_extra_ns", "nanosort_us", "millisort_us"],
    );
    for extra in [0u64, 2000, 4000] {
        let nr = Scenario::new(NanoSort { keys_per_node: 16, ..Default::default() })
            .nodes(256)
            .net(tail_net((1, 100), extra))
            .seed(3)
            .run()?;

        let mr = Scenario::new(MilliSort::default())
            .nodes(128)
            .net(tail_net((1, 100), extra))
            .seed(3)
            .run()?;
        assert!(nr.validation.ok() && mr.validation.ok());
        t3.row(vec![
            extra.to_string(),
            format!("{:.2}", nr.runtime().as_us_f64()),
            format!("{:.2}", mr.runtime().as_us_f64()),
        ]);
    }
    println!("{}", t3.render());
    Ok(())
}
