//! End-to-end driver for the paper's §6.3 headline experiment:
//! **sort 1M keys on 65,536 simulated nanoPU cores** under the GraySort
//! benchmark (104 B records: keys shuffle with origin ids, then values are
//! redistributed), repeated over several seeds, reporting the Table 2
//! throughput row. This is the workload-proof that all layers compose:
//!
//! 1. a small XLA-data-plane run first (every local sort / bucketize /
//!    median executed via Pallas → JAX → HLO → PJRT artifacts), validated;
//! 2. the full 65,536-core fleet with the native data plane (bit-identical
//!    semantics, cross-checked in tests), 10 runs, mean/σ vs the paper.
//!
//! Both phases run the same `NanoSort` workload through the `Scenario`
//! API — only the environment (fleet size, data plane, seed) changes.
//!
//! ```sh
//! make artifacts && cargo run --release --example graysort_datacenter
//! # faster: cargo run --release --example graysort_datacenter -- --quick
//! ```

use nanosort::benchfig::{headline_workload, HEADLINE_KEYS_PER_NODE};
use nanosort::coordinator::ComputeChoice;
use nanosort::graysort::Throughput;
use nanosort::scenario::Scenario;
use nanosort::stats::Summary;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let skip_xla = std::env::args().any(|a| a == "--no-xla");

    // Phase 1: three-layer composition proof at 4,096 cores.
    if !skip_xla {
        match ComputeChoice::Xla.build() {
            Ok(compute) => {
                let nodes = if quick { 256 } else { 4096 };
                let kpn = HEADLINE_KEYS_PER_NODE;
                println!("[phase 1] XLA data plane: {} keys on {nodes} cores ...", nodes * kpn);
                let t0 = std::time::Instant::now();
                let r = Scenario::new(headline_workload())
                    .nodes(nodes)
                    .seed(7)
                    .compute_with(compute)
                    .run()?;
                println!(
                    "[phase 1] simulated {:.2} µs | valid={} | wall {:.1?}",
                    r.runtime().as_us_f64(),
                    r.validation.ok(),
                    t0.elapsed()
                );
                assert!(r.validation.ok(), "XLA-data-plane run failed validation");
            }
            Err(e) => {
                eprintln!("[phase 1] skipped — artifacts unavailable: {e:#}");
                eprintln!("          run `make artifacts` for the full three-layer proof");
            }
        }
    }

    // Phase 2: the 65,536-core headline fleet.
    let nodes = if quick { 4096 } else { 65_536 };
    let runs = if quick { 3 } else { 10 };
    let kpn = HEADLINE_KEYS_PER_NODE;
    println!("\n[phase 2] headline: {kpn} keys/core on {nodes} cores, {runs} runs");
    let mut times = Vec::new();
    for run in 0..runs {
        let t0 = std::time::Instant::now();
        let r = Scenario::new(headline_workload())
            .nodes(nodes)
            .seed(100 + run as u64)
            .run()?;
        assert!(r.validation.ok(), "run {run} failed validation");
        let us = r.runtime().as_us_f64();
        times.push(us);
        println!(
            "  run {:>2}: {:>7.2} µs  (skew {:.2}, {} msgs, wall {:.1?})",
            run + 1,
            us,
            r.metric_f64("skew").unwrap_or(1.0),
            r.summary.net.msgs_sent,
            t0.elapsed()
        );
        if run == 0 {
            let tput = Throughput { records: nodes * kpn, cores: nodes, runtime: r.runtime() };
            println!(
                "  Table 2 row: {} cores | {:.0} µs | {:.0} records/ms/core | {:.2} GB/s aggregate",
                nodes,
                us,
                tput.records_per_ms_per_core(),
                tput.gb_per_s()
            );
        }
    }
    let s = Summary::of(&times);
    println!(
        "\nheadline: mean {:.1} µs | σ {:.3} µs | min {:.1} | max {:.1} over {} runs",
        s.mean, s.std, s.min, s.max, s.n
    );
    println!("paper:    mean 68 µs | σ 4.127 µs | all 10 runs < 78 µs");
    Ok(())
}
