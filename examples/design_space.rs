//! Design-space exploration (paper §3/§6.1-6.2): the granularity dials.
//!
//! Sweeps the three coupled knobs the paper identifies — task size
//! (keys/core), tree incast (width vs depth), and bucket count — and
//! prints where the sweet spots fall on this substrate. Every run goes
//! through the unified `Scenario` API.
//!
//! ```sh
//! cargo run --release --example design_space
//! ```

use nanosort::algo::mergemin::MergeMin;
use nanosort::algo::nanosort::NanoSort;
use nanosort::coordinator::Table;
use nanosort::scenario::Scenario;

fn main() -> anyhow::Result<()> {
    // Dial 1: MergeMin incast (Fig 4's trade-off, multiple fleet sizes).
    let mut t1 = Table::new(
        "MergeMin: incast sweet spot vs fleet size (128 values/core)",
        &["cores", "incast=2", "incast=4", "incast=8", "incast=16", "incast=64"],
    );
    for cores in [64usize, 256, 1024] {
        let mut cells = vec![cores.to_string()];
        for incast in [2usize, 4, 8, 16, 64] {
            let r = Scenario::new(MergeMin { values_per_core: 128, incast })
                .nodes(cores)
                .seed(1)
                .run()?;
            assert!(r.validation.ok());
            cells.push(format!("{:.0}ns", r.summary.makespan.as_ns_f64()));
        }
        t1.row(cells);
    }
    t1.note("paper Fig 4: sweet spot at incast 8 for 64 cores");
    println!("{}", t1.render());

    // Dial 2: NanoSort granularity — fixed 65,536 keys, vary the fleet.
    let mut t2 = Table::new(
        "NanoSort: same 65,536 keys, more (smaller) tasks",
        &["cores", "keys_per_core", "runtime_us", "aggregate_core_us"],
    );
    for (nodes, kpn) in [(256usize, 256usize), (4096, 16), (65536, 1)] {
        let r = Scenario::new(NanoSort { keys_per_node: kpn, ..Default::default() })
            .nodes(nodes)
            .seed(5)
            .run()?;
        assert!(r.validation.ok());
        let us = r.runtime().as_us_f64();
        t2.row(vec![
            nodes.to_string(),
            kpn.to_string(),
            format!("{us:.2}"),
            format!("{:.0}", us * nodes as f64),
        ]);
    }
    t2.note("latency falls as tasks shrink — but aggregate core-time (cost) rises");
    println!("{}", t2.render());

    // Dial 3: median-tree incast within NanoSort (4,096 cores).
    let mut t3 = Table::new(
        "NanoSort: median-tree incast (4,096 cores, 16 keys/core, b=16)",
        &["median_incast", "runtime_us"],
    );
    for f in [2usize, 4, 8, 16] {
        let r = Scenario::new(NanoSort { median_incast: f, ..Default::default() })
            .nodes(4096)
            .seed(5)
            .run()?;
        assert!(r.validation.ok());
        t3.row(vec![f.to_string(), format!("{:.2}", r.runtime().as_us_f64())]);
    }
    println!("{}", t3.render());

    // Dial 4: buckets per level (Fig 11 shape).
    let mut t4 = Table::new(
        "NanoSort: buckets per level (4,096 cores, 32 keys/core)",
        &["buckets", "depth", "runtime_us", "msgs_sent"],
    );
    for b in [4usize, 8, 16] {
        let r = Scenario::new(NanoSort {
            keys_per_node: 32,
            buckets: b,
            median_incast: b,
            ..Default::default()
        })
        .nodes(4096)
        .seed(5)
        .run()?;
        assert!(r.validation.ok());
        t4.row(vec![
            b.to_string(),
            r.metric_u64("depth").unwrap_or(0).to_string(),
            format!("{:.2}", r.runtime().as_us_f64()),
            r.summary.net.msgs_sent.to_string(),
        ]);
    }
    t4.note("paper Fig 11: similar runtime despite different traffic (width/depth trade)");
    println!("{}", t4.render());
    Ok(())
}
