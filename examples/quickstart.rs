//! Quickstart: sort 4,096 keys across 256 simulated nanoPU cores with the
//! full three-layer stack — node-local compute runs through the
//! AOT-compiled Pallas/JAX artifacts via PJRT (`--native` falls back to
//! the pure-Rust data plane if artifacts aren't built). The run goes
//! through the unified `Scenario` API.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use nanosort::algo::nanosort::NanoSort;
use nanosort::coordinator::ComputeChoice;
use nanosort::scenario::Scenario;

fn main() -> anyhow::Result<()> {
    let native = std::env::args().any(|a| a == "--native");
    let choice = if native { ComputeChoice::Native } else { ComputeChoice::Xla };
    let compute = match choice.build() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("XLA data plane unavailable ({e:#}); run `make artifacts` first.");
            eprintln!("Falling back to the native data plane.\n");
            ComputeChoice::Native.build()?
        }
    };
    println!("data plane: {}", compute.name());

    let workload = NanoSort {
        keys_per_node: 16,
        buckets: 16,
        median_incast: 16,
        shuffle_values: true, // full GraySort semantics: values travel too
        ..Default::default()
    };
    let nodes = 256;
    println!(
        "sorting {} keys on {} cores ({} buckets)...",
        nodes * workload.keys_per_node,
        nodes,
        workload.buckets
    );

    let r = Scenario::new(workload).nodes(nodes).seed(42).compute_with(compute).run()?;

    let sort = r.validation.sort.as_ref().expect("nanosort validation");
    println!("simulated runtime : {:.2} µs", r.runtime().as_us_f64());
    println!("globally sorted   : {}", sort.globally_sorted);
    println!("permutation intact: {}", sort.is_permutation);
    println!("values intact     : {}", sort.values_intact);
    println!("final skew        : {:.2}", r.metric_f64("skew").unwrap_or(1.0));
    println!("messages sent     : {}", r.summary.net.msgs_sent);
    println!("mean utilization  : {:.1} %", 100.0 * r.summary.mean_utilization());
    for l in &r.stages {
        println!(
            "  stage {}: busy {:.2} µs (mean) / idle {:.2} µs (mean)",
            l.stage, l.mean_busy_us, l.mean_idle_us
        );
    }
    assert!(r.validation.ok(), "validation failed");
    println!("OK");
    Ok(())
}
