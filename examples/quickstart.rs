//! Quickstart: sort 4,096 keys across 256 simulated nanoPU cores with the
//! full three-layer stack — node-local compute runs through the
//! AOT-compiled Pallas/JAX artifacts via PJRT (`--native` falls back to
//! the pure-Rust data plane if artifacts aren't built).
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use nanosort::algo::nanosort::{run_nanosort, NanoSortConfig};
use nanosort::coordinator::ComputeChoice;

fn main() -> anyhow::Result<()> {
    let native = std::env::args().any(|a| a == "--native");
    let choice = if native { ComputeChoice::Native } else { ComputeChoice::Xla };
    let compute = match choice.build() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("XLA data plane unavailable ({e:#}); run `make artifacts` first.");
            eprintln!("Falling back to the native data plane.\n");
            ComputeChoice::Native.build()?
        }
    };
    println!("data plane: {}", compute.name());

    let cfg = NanoSortConfig {
        nodes: 256,
        keys_per_node: 16,
        buckets: 16,
        median_incast: 16,
        shuffle_values: true, // full GraySort semantics: values travel too
        seed: 42,
        ..Default::default()
    };
    println!(
        "sorting {} keys on {} cores ({} buckets, depth {})...",
        cfg.total_keys(),
        cfg.nodes,
        cfg.buckets,
        cfg.depth()
    );

    let r = run_nanosort(&cfg, compute);

    println!("simulated runtime : {:.2} µs", r.runtime().as_us_f64());
    println!("globally sorted   : {}", r.validation.globally_sorted);
    println!("permutation intact: {}", r.validation.is_permutation);
    println!("values intact     : {}", r.validation.values_intact);
    println!("final skew        : {:.2}", r.skew);
    println!("messages sent     : {}", r.summary.net.msgs_sent);
    println!("mean utilization  : {:.1} %", 100.0 * r.summary.mean_utilization());
    for l in &r.levels {
        println!(
            "  stage {}: busy {:.2} µs (mean) / idle {:.2} µs (mean)",
            l.stage, l.mean_busy_us, l.mean_idle_us
        );
    }
    assert!(r.validation.ok(), "validation failed");
    println!("OK");
    Ok(())
}
