"""Shared pytest config: enable x64 before any kernel import (u64 keys)."""

import jax

jax.config.update("jax_enable_x64", True)
