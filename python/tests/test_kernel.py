"""L1 kernel vs pure-jnp oracle — the CORE correctness signal.

Integer keys, so every comparison is bit-exact (array_equal, no tolerance).
Hypothesis sweeps shapes and adversarial value patterns.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import bitonic, bucketize, merge_min, ref

POW2 = [2, 4, 8, 16, 32, 64, 128, 256]


def _rand_u64(rng, shape):
    return jnp.asarray(rng.integers(0, 2**64, size=shape, dtype=np.uint64))


# ---------------------------------------------------------------- bitonic
@pytest.mark.parametrize("n", POW2)
@pytest.mark.parametrize("b", [1, 3, 17])
def test_sort_matches_ref(b, n):
    rng = np.random.default_rng(b * 1000 + n)
    x = _rand_u64(rng, (b, n))
    out = bitonic.sort_blocks(x)
    assert jnp.array_equal(out, ref.sort_blocks_ref(x))


@pytest.mark.parametrize("n", [16, 64])
def test_sort_edge_patterns(n):
    patterns = [
        jnp.zeros((1, n), jnp.uint64),
        jnp.full((1, n), jnp.uint64(2**64 - 1)),
        jnp.arange(n, dtype=jnp.uint64)[None, :],
        jnp.arange(n, dtype=jnp.uint64)[None, ::-1],
        jnp.asarray(np.tile([5, 3], n // 2)[None, :].astype(np.uint64)),
    ]
    for x in patterns:
        assert jnp.array_equal(bitonic.sort_blocks(x), ref.sort_blocks_ref(x))


def test_sort_is_permutation():
    rng = np.random.default_rng(7)
    x = _rand_u64(rng, (4, 64))
    out = np.asarray(bitonic.sort_blocks(x))
    for row_in, row_out in zip(np.asarray(x), out):
        assert sorted(row_in.tolist()) == row_out.tolist()


def test_sort_rejects_non_pow2():
    with pytest.raises(ValueError, match="power-of-two"):
        bitonic.bitonic_sort_array(jnp.zeros((1, 12), jnp.uint64))


@settings(max_examples=40, deadline=None)
@given(
    n_exp=st.integers(1, 7),
    b=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
    clustered=st.booleans(),
)
def test_sort_hypothesis(n_exp, b, seed, clustered):
    n = 1 << n_exp
    rng = np.random.default_rng(seed)
    if clustered:  # heavy duplicates — the paper assumes distinct keys but
        # the kernel must tolerate ties (stability is irrelevant: keys only)
        x = jnp.asarray(rng.integers(0, 4, size=(b, n), dtype=np.uint64))
    else:
        x = _rand_u64(rng, (b, n))
    assert jnp.array_equal(bitonic.sort_blocks(x), ref.sort_blocks_ref(x))


# -------------------------------------------------------------- merge_min
@pytest.mark.parametrize("n", POW2)
def test_merge_min_matches_ref(n):
    rng = np.random.default_rng(n)
    x = _rand_u64(rng, (5, n))
    assert jnp.array_equal(merge_min.merge_min_blocks(x), ref.merge_min_blocks_ref(x))


@settings(max_examples=30, deadline=None)
@given(n_exp=st.integers(0, 7), b=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_merge_min_hypothesis(n_exp, b, seed):
    n = 1 << n_exp
    rng = np.random.default_rng(seed)
    x = _rand_u64(rng, (b, n))
    assert jnp.array_equal(merge_min.merge_min_blocks(x), ref.merge_min_blocks_ref(x))


def test_merge_min_extremes():
    x = jnp.asarray(
        np.array([[2**64 - 1, 0, 5, 9], [7, 7, 7, 7]], dtype=np.uint64)
    )
    out = merge_min.merge_min_blocks(x)
    assert out.tolist() == [0, 7]


# -------------------------------------------------------------- bucketize
@pytest.mark.parametrize("p", [1, 3, 7, 15])
@pytest.mark.parametrize("n", [16, 32, 64])
def test_bucketize_matches_ref(n, p):
    rng = np.random.default_rng(n * 100 + p)
    keys = _rand_u64(rng, (3, n))
    pivots = jnp.sort(_rand_u64(rng, (p,)))
    out = bucketize.bucketize_blocks(keys, pivots)
    assert jnp.array_equal(out, ref.bucketize_blocks_ref(keys, pivots))
    assert int(out.max()) <= p and int(out.min()) >= 0


def test_bucketize_boundaries():
    # keys exactly equal to pivots go right (bucket i+1), per side='right'.
    pivots = jnp.asarray(np.array([10, 20, 30], dtype=np.uint64))
    keys = jnp.asarray(np.array([[0, 10, 15, 20, 30, 31, 9, 29]], dtype=np.uint64))
    out = bucketize.bucketize_blocks(keys, pivots)
    assert out.tolist() == [[0, 1, 1, 2, 3, 3, 0, 2]]


@settings(max_examples=30, deadline=None)
@given(
    n_exp=st.integers(1, 6),
    p=st.sampled_from([1, 3, 7, 15]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bucketize_hypothesis(n_exp, p, seed):
    n = 1 << n_exp
    rng = np.random.default_rng(seed)
    keys = _rand_u64(rng, (2, n))
    pivots = jnp.sort(_rand_u64(rng, (p,)))
    assert jnp.array_equal(
        bucketize.bucketize_blocks(keys, pivots),
        ref.bucketize_blocks_ref(keys, pivots),
    )
