"""L2 entry-point tests: shapes, dtypes, and semantics of model.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import ref


def _rand_u64(rng, shape):
    return jnp.asarray(rng.integers(0, 2**64, size=shape, dtype=np.uint64))


def test_sort_block_tuple_shape():
    rng = np.random.default_rng(0)
    x = _rand_u64(rng, (4, 32))
    (out,) = model.sort_block(x)
    assert out.shape == (4, 32) and out.dtype == jnp.uint64
    assert jnp.array_equal(out, ref.sort_blocks_ref(x))


def test_sort_stats_block():
    rng = np.random.default_rng(1)
    x = _rand_u64(rng, (3, 16))
    s, lo, hi = model.sort_stats_block(x)
    assert jnp.array_equal(lo, x.min(axis=-1))
    assert jnp.array_equal(hi, x.max(axis=-1))
    assert jnp.array_equal(s, ref.sort_blocks_ref(x))


def test_bucketize_block():
    rng = np.random.default_rng(2)
    keys = _rand_u64(rng, (2, 32))
    pivots = jnp.sort(_rand_u64(rng, (15,)))
    (out,) = model.bucketize_block(keys, pivots)
    assert out.dtype == jnp.int32
    assert jnp.array_equal(out, ref.bucketize_blocks_ref(keys, pivots))


def test_merge_min_block():
    rng = np.random.default_rng(3)
    x = _rand_u64(rng, (6, 64))
    (out,) = model.merge_min_block(x)
    assert jnp.array_equal(out, x.min(axis=-1))


@pytest.mark.parametrize("m", [2, 3, 4, 5, 8, 16])
def test_median_combine(m):
    rng = np.random.default_rng(m)
    stacked = _rand_u64(rng, (m, 15))
    (out,) = model.median_combine(stacked)
    assert jnp.array_equal(out, ref.median_combine_ref(stacked))


@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 16), p=st.sampled_from([3, 7, 15]), seed=st.integers(0, 2**31 - 1))
def test_median_combine_hypothesis(m, p, seed):
    rng = np.random.default_rng(seed)
    stacked = _rand_u64(rng, (m, p))
    (out,) = model.median_combine(stacked)
    assert jnp.array_equal(out, ref.median_combine_ref(stacked))


def test_median_combine_is_order_stat():
    # median of known columns
    stacked = jnp.asarray(
        np.array([[1, 100], [2, 200], [3, 300], [4, 400], [5, 500]], dtype=np.uint64)
    )
    (out,) = model.median_combine(stacked)
    assert out.tolist() == [3, 300]


def test_entry_points_lower_to_hlo():
    """Every AOT entry point must lower to HLO text with a u64 signature."""
    from compile.aot import to_hlo_text

    u = jax.ShapeDtypeStruct((1, 16), jnp.uint64)
    for name, fn in model.ENTRY_POINTS.items():
        if name == "bucketize_block":
            args = (u, jax.ShapeDtypeStruct((15,), jnp.uint64))
        elif name == "median_combine":
            args = (jax.ShapeDtypeStruct((4, 15), jnp.uint64),)
        else:
            args = (u,)
        text = to_hlo_text(jax.jit(fn).lower(*args))
        assert "ENTRY" in text and "u64" in text, name
