"""L1 Pallas kernel: batched bitonic sort of u64 keys.

This is the per-core compute hot-spot of NanoSort (paper Fig 1: "sort 40
8-byte keys" is a canonical sub-microsecond nanoTask, Fig 8: local sort).
Each simulated nanoPU core owns a small block of keys (<= 256); the kernel
sorts B such blocks in one launch, one grid step per block.

TPU adaptation (DESIGN.md "Hardware-Adaptation"): one VMEM-resident block
per grid step via BlockSpec((1, N)), compare-exchange stages as branch-free
vector ops (VPU work, no MXU). interpret=True is mandatory on this image:
real TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(x, j, k):
    """One bitonic compare-exchange stage over the last axis.

    ``j`` is the partner distance, ``k`` the (power-of-two) size of the
    bitonic blocks being merged; both are static Python ints so the whole
    network unrolls into straight-line vector code.
    """
    n = x.shape[-1]
    idx = jnp.arange(n, dtype=jnp.int32)
    partner = idx ^ j
    xp = jnp.take(x, partner, axis=-1)
    # Ascending iff bit k of the index is clear (standard bitonic network).
    ascending = (idx & k) == 0
    keep_lo = (idx < partner) == ascending
    return jnp.where(keep_lo, jnp.minimum(x, xp), jnp.maximum(x, xp))


def bitonic_sort_array(x):
    """Sort the last axis of ``x`` with a full bitonic network (jnp ops).

    Shared by the Pallas kernel body and (for cross-checking) callable on
    plain arrays. Last-axis length must be a power of two.
    """
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"bitonic sort needs a power-of-two length, got {n}")
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            x = _compare_exchange(x, j, k)
            j //= 2
        k *= 2
    return x


def _sort_kernel(x_ref, o_ref):
    o_ref[...] = bitonic_sort_array(x_ref[...])


@functools.partial(jax.jit, static_argnames=())
def sort_blocks(x):
    """Sort each row of ``x: u64[B, N]`` (N a power of two) ascending."""
    b, n = x.shape
    return pl.pallas_call(
        _sort_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        interpret=True,
    )(x)
