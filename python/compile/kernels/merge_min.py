"""L1 Pallas kernel: batched min-reduction (the MergeMin merge step).

Paper Section 3.1 / Fig 4: each merge-tree worker reduces the minima it
receives from its children. The kernel reduces B incast blocks at once,
one grid step per block (VMEM-resident, tree-reduce on the VPU).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _min_kernel(x_ref, o_ref):
    o_ref[...] = jnp.min(x_ref[...], axis=-1)


def merge_min_blocks(x):
    """Minimum of each row of ``x: u64[B, N]`` -> ``u64[B]``."""
    b, n = x.shape
    return pl.pallas_call(
        _min_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), x.dtype),
        interpret=True,
    )(x)
