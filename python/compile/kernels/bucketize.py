"""L1 Pallas kernel: branch-free bucket routing (the NanoSort shuffle step).

Given P = b-1 sorted pivots, every key maps to bucket
``sum(key >= pivot_i)`` in [0, b). Paper Section 4's shuffle routes each
key to a uniformly random node of its bucket's partition; the bucket index
computed here is the data-dependent half of that routing decision.

Branch-free comparison-sum instead of binary search: P <= 15, so the
broadcast-compare is a handful of vector ops per block — ideal VPU shape.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bucketize_kernel(keys_ref, pivots_ref, o_ref):
    keys = keys_ref[...]  # [1, N]
    pivots = pivots_ref[...]  # [P]
    ge = keys[..., None] >= pivots[None, None, :]  # [1, N, P]
    o_ref[...] = jnp.sum(ge.astype(jnp.int32), axis=-1)


def bucketize_blocks(keys, pivots):
    """Bucket index of each key: ``u64[B, N], u64[P] -> i32[B, N]``."""
    b, n = keys.shape
    (p,) = pivots.shape
    return pl.pallas_call(
        _bucketize_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        interpret=True,
    )(keys, pivots)
