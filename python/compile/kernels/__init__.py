"""L1 Pallas kernels for the NanoSort per-core compute hot-spots.

- ``bitonic``: batched local key sort (the nanoTask "sort <= 64 keys").
- ``merge_min``: batched min-reduce (MergeMin merge-tree step).
- ``bucketize``: branch-free key -> bucket routing (shuffle step).
- ``ref``: pure-jnp oracles for all of the above.
"""

from . import bitonic, bucketize, merge_min, ref  # noqa: F401
