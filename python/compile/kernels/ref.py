"""Pure-jnp correctness oracles for the L1 kernels.

Every kernel must match its oracle bit-exactly (integer keys, no tolerance)
— checked by python/tests and, cross-language, by the Rust NativeCompute
oracle in rust/src/runtime.
"""

import jax.numpy as jnp


def sort_blocks_ref(x):
    """Oracle for kernels.bitonic.sort_blocks."""
    return jnp.sort(x, axis=-1)


def merge_min_blocks_ref(x):
    """Oracle for kernels.merge_min.merge_min_blocks."""
    return jnp.min(x, axis=-1)


def bucketize_blocks_ref(keys, pivots):
    """Oracle for kernels.bucketize.bucketize_blocks.

    Bucket of key k given sorted pivots p_1..p_P is |{i : k >= p_i}|,
    i.e. ``searchsorted(pivots, key, side='right')``.
    """
    return jnp.searchsorted(pivots, keys, side="right").astype(jnp.int32)


def median_combine_ref(stacked):
    """Oracle for model.median_combine: element-wise lower median."""
    m = stacked.shape[0]
    return jnp.sort(stacked, axis=0)[(m - 1) // 2]
