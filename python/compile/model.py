"""L2: the JAX compute graph that the Rust coordinator AOT-loads.

Each public function here is an AOT entry point: ``aot.py`` lowers it for a
fixed set of static shapes to HLO text and the Rust ``runtime::XlaEngine``
executes it on the request path. Every entry point routes its hot loop
through an L1 Pallas kernel so the whole three-layer stack is exercised.

The paper's per-core compute (Section 5.2, Figs 1/2/8) decomposes into:
  - ``sort_block``       — local key sort (NanoSort step 2a, MilliSort local sort)
  - ``sort_stats_block`` — sort + the order statistics PivotSelect consumes
  - ``bucketize_block``  — pivot routing for the shuffle (NanoSort step 2c)
  - ``merge_min_block``  — MergeMin's reduce
  - ``median_combine``   — median-tree aggregation (element-wise median of
                           child pivot vectors; NanoSort step 2b)
"""

import jax
import jax.numpy as jnp

from .kernels import bitonic, bucketize, merge_min


def sort_block(x):
    """Sort each row of ``u64[B, N]`` ascending (N a power of two)."""
    return (bitonic.sort_blocks(x),)


def sort_stats_block(x):
    """Sort rows and return (sorted, row_min, row_max).

    The min/max order statistics come for free after the sort and feed the
    skew / sanity accounting in the coordinator.
    """
    s = bitonic.sort_blocks(x)
    return (s, s[:, 0], s[:, -1])


def bucketize_block(keys, pivots):
    """Bucket index of each key against sorted pivots: ``-> i32[B, N]``."""
    return (bucketize.bucketize_blocks(keys, pivots),)


def merge_min_block(x):
    """Row-wise minimum: ``u64[B, N] -> u64[B]``."""
    return (merge_min.merge_min_blocks(x),)


def median_combine(stacked):
    """Element-wise lower median across axis 0: ``u64[M, P] -> u64[P]``.

    This is the aggregation a median-tree node performs: it holds M child
    pivot vectors and emits the per-position median. M is a tree incast
    (<= 16), P = b-1 pivots; the sort over the tiny M axis reuses the
    bitonic kernel by padding M to a power of two with +inf sentinels.
    """
    m, p = stacked.shape
    mp = 1 << (m - 1).bit_length()  # next power of two
    if mp != m:
        pad = jnp.full((mp - m, p), jnp.uint64(2**64 - 1), dtype=stacked.dtype)
        stacked = jnp.concatenate([stacked, pad], axis=0)
    # Sort columns: transpose so each column becomes a row block.
    cols = stacked.T  # [P, mp]
    cols_sorted = bitonic.sort_blocks(cols)
    return (cols_sorted[:, (m - 1) // 2],)


ENTRY_POINTS = {
    "sort_block": sort_block,
    "sort_stats_block": sort_stats_block,
    "bucketize_block": bucketize_block,
    "merge_min_block": merge_min_block,
    "median_combine": median_combine,
}
