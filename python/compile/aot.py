"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via ``make artifacts``; writes ``artifacts/<name>.hlo.txt`` plus
``artifacts/manifest.json`` describing each entry point's shapes so the
rust ``runtime::ArtifactRegistry`` can load them without guessing.

Python runs ONLY here, at build time — never on the request path.
"""

import argparse
import hashlib
import json
import pathlib

import jax

jax.config.update("jax_enable_x64", True)  # u64 keys end-to-end

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

U64 = jnp.uint64

# (entry point, artifact name, example-arg shapes).
# B = simulated-core batch, N = keys per core (power of two), P = pivots,
# M = median-tree incast. The set covers every shape the L3 coordinator
# requests for the paper's experiments (DESIGN.md section 4).
SPECS = []


def _spec(entry, name, *args):
    SPECS.append((entry, name, args))


def _u(shape):
    return jax.ShapeDtypeStruct(shape, U64)


# Local sort: per-node blocks and fleet-batched blocks.
for b, n in [(1, 16), (1, 32), (1, 64), (1, 128), (1, 256),
             (64, 128), (256, 32), (4096, 16), (4096, 32)]:
    _spec("sort_block", f"sort_block_b{b}_n{n}", _u((b, n)))

# Sort + order statistics (pivot-select front half).
for b, n in [(1, 16), (1, 32), (1, 64)]:
    _spec("sort_stats_block", f"sort_stats_block_b{b}_n{n}", _u((b, n)))

# Shuffle routing: keys x pivots -> bucket ids.
for b, n, p in [(1, 16, 15), (1, 32, 15), (1, 64, 15), (1, 32, 7),
                (1, 32, 3), (4096, 16, 15), (4096, 32, 15), (4096, 32, 7), (4096, 32, 3)]:
    _spec("bucketize_block", f"bucketize_block_b{b}_n{n}_p{p}", _u((b, n)), _u((p,)))

# MergeMin reduce: incast blocks.
for b, n in [(1, 2), (1, 4), (1, 8), (1, 16), (1, 32), (1, 64), (1, 128), (64, 128)]:
    _spec("merge_min_block", f"merge_min_block_b{b}_n{n}", _u((b, n)))

# Median-tree aggregation: M child pivot vectors -> element-wise median.
for m, p in [(2, 15), (4, 15), (8, 15), (16, 15), (4, 7), (8, 7), (8, 3), (4, 3)]:
    _spec("median_combine", f"median_combine_m{m}_p{p}", _u((m, p)))


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: pathlib.Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"format": "hlo-text", "key_dtype": "u64", "artifacts": []}
    for entry, name, args in SPECS:
        fn = model.ENTRY_POINTS[entry]
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "entry": entry,
                "file": path.name,
                "inputs": [
                    {"dtype": str(a.dtype), "shape": list(a.shape)} for a in args
                ],
                "outputs": [
                    {"dtype": str(o.dtype), "shape": list(o.shape)}
                    for o in jax.eval_shape(fn, *args)
                ],
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            }
        )
        print(f"  {name}: {len(text)} chars")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    # TSV twin of the manifest for the (dependency-free) rust parser:
    #   name \t entry \t file \t inputs \t outputs
    # where inputs/outputs are `dtype:dim,dim;dtype:dim` lists.
    def fmt(tensors):
        return ";".join(
            f"{t['dtype']}:{','.join(str(d) for d in t['shape'])}" for t in tensors
        )

    lines = ["#format=hlo-text\tkey_dtype=u64"]
    for a in manifest["artifacts"]:
        lines.append(
            "\t".join([a["name"], a["entry"], a["file"], fmt(a["inputs"]), fmt(a["outputs"])])
        )
    (out_dir / "manifest.tsv").write_text("\n".join(lines) + "\n")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    manifest = build(out)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json to {out}")


if __name__ == "__main__":
    main()
